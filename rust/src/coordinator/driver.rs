//! The distributed even-odd hopping driver: EO1 -> post sends -> bulk
//! (overlapped with the wire) -> wait -> EO2, with every phase charged to
//! the FAPP-analog profiler. This is the per-rank pipeline of §3.5-3.6.

use crate::algebra::Real;
use crate::comm::halo::HaloPlans;
use crate::comm::unpack::{MultiEo2Tail, RecvBuffers};
use crate::comm::{balance, pack, tags, unpack, validate_wire_format, wire_sig, Comm, CommScalar};
use crate::dslash::{HoppingEo, LinkSource, MultiStoreTail, StoreTail, WrapMode};
use crate::field::{FermionField, MultiFermionField};
use crate::lattice::{Dir, Geometry, Parity};

use super::profiler::{Phase, Profiler};
use super::team::{chunk_range, SendPtr, Team};

/// EO2 thread-partition policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Eo2Schedule {
    /// equal site counts (the paper's current scheme; Fig. 9 imbalance)
    Uniform,
    /// cost-weighted static partition (the paper's proposed future work)
    Balanced,
}

impl Eo2Schedule {
    /// Parse the CLI/config spelling ("uniform" | "balanced").
    pub fn parse(s: &str) -> Result<Eo2Schedule, String> {
        match s {
            "uniform" => Ok(Eo2Schedule::Uniform),
            "balanced" => Ok(Eo2Schedule::Balanced),
            _ => Err(format!(
                "eo2 schedule must be \"uniform\" or \"balanced\", got {s:?}"
            )),
        }
    }
}

impl std::fmt::Display for Eo2Schedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Eo2Schedule::Uniform => "uniform",
            Eo2Schedule::Balanced => "balanced",
        })
    }
}

/// Per-RHS fused tail of the batched distributed hopping: the analog of
/// the `a`/`b` xpay arguments of [`DistHopping::hopping_fused`], with a
/// gamma5 flavor so the distributed normal operator can fuse both of its
/// gamma5 passes into the EO2 merge (or the bulk store when nothing
/// communicates) exactly like the native [`crate::dslash::MultiStoreTail`].
#[derive(Clone, Copy)]
pub enum MultiHopTail<'a, R: Real> {
    /// out_r = H psi_r
    Assign,
    /// out_r = a * (H psi_r) + b_r
    Xpay { a: R, b: &'a MultiFermionField<R> },
    /// out_r = gamma5 * (a * (H psi_r) + b_r)
    Gamma5Xpay { a: R, b: &'a MultiFermionField<R> },
}

/// Distributed even-odd hopping operator for one rank.
pub struct DistHopping {
    pub geom: Geometry,
    pub comm_dirs: [bool; 4],
    bulk: HoppingEo,
    plans: [HaloPlans; 2],
    pub schedule: Eo2Schedule,
    /// cached balanced chunks per parity (computed on demand)
    chunks: [Vec<(usize, usize)>; 2],
    /// site-uniform chunks per parity, used when a fused tail makes the
    /// EO2 pass cost uniform per site (the balanced chunks weight halo
    /// imports only and would serialize the tail on one thread)
    tail_chunks: [Vec<(usize, usize)>; 2],
    nthreads: usize,
}

impl DistHopping {
    /// `force_comm` routes even self-neighbor directions through the
    /// communication path, as the paper does in all its measurements.
    pub fn new(
        geom: &Geometry,
        force_comm: bool,
        nthreads: usize,
        schedule: Eo2Schedule,
    ) -> DistHopping {
        DistHopping::with_chunking(geom, force_comm, nthreads, schedule, 1)
    }

    /// [`Self::new`] with an explicit EO2 chunk-boundary granularity for
    /// the balanced schedule (sites; 1 = exact cost boundaries). The
    /// partition only moves WHICH thread merges which sites — the
    /// per-site arithmetic is unchanged, so any granularity produces
    /// bit-identical fields (pinned by `tests/tune.rs`).
    pub fn with_chunking(
        geom: &Geometry,
        force_comm: bool,
        nthreads: usize,
        schedule: Eo2Schedule,
        granularity: usize,
    ) -> DistHopping {
        let comm_dirs =
            std::array::from_fn(|d| force_comm || geom.grid.0[d] > 1);
        let wrap = std::array::from_fn(|d| {
            if comm_dirs[d] {
                WrapMode::SkipBoundary
            } else {
                WrapMode::Periodic
            }
        });
        let plans = [
            HaloPlans::new(geom, Parity::Even, comm_dirs),
            HaloPlans::new(geom, Parity::Odd, comm_dirs),
        ];
        let chunks = std::array::from_fn(|p| match schedule {
            Eo2Schedule::Uniform => balance::uniform_chunks(plans[p].nsites, nthreads),
            Eo2Schedule::Balanced => {
                balance::balanced_chunks_granular(&plans[p], nthreads, granularity)
            }
        });
        let tail_chunks =
            std::array::from_fn(|p| balance::uniform_chunks(plans[p].nsites, nthreads));
        DistHopping {
            geom: *geom,
            comm_dirs,
            bulk: HoppingEo::with_wrap(geom, wrap),
            plans,
            schedule,
            chunks,
            tail_chunks,
            nthreads,
        }
    }

    pub fn plans(&self, p_out: Parity) -> &HaloPlans {
        &self.plans[p_out.index()]
    }

    /// out = H_{p_out <- 1-p_out} psi across the rank world. Generic over
    /// the field precision (halo buffers and the wire payload follow `R`)
    /// and the [`LinkSource`]: the bulk kernel streams full or two-row
    /// compressed link tiles, and the EO1 pack / EO2 merge fetch their
    /// per-site links from the same source. Only spinor half-halos ever
    /// hit the wire, so compression changes no message.
    pub fn hopping<R: Real + CommScalar, U: LinkSource<R>>(
        &self,
        out: &mut FermionField<R>,
        u: &U,
        psi: &FermionField<R>,
        p_out: Parity,
        comm: &mut Comm,
        team: &mut Team,
        prof: &Profiler,
    ) {
        self.hopping_inner(out, u, psi, p_out, comm, team, prof, None);
    }

    /// [`Self::hopping`] with the M-hat xpay tail `out = a * (H psi) + b`
    /// fused into the pipeline instead of running as a separate
    /// full-field sweep afterwards (ROADMAP PR 2 follow-up):
    ///
    /// * when no direction communicates, the bulk kernel covers every
    ///   site and stores through [`StoreTail::Xpay`] — zero extra passes;
    /// * otherwise the bulk stores plain and EO2 applies the tail per
    ///   site in the same pass that merges the halo contributions
    ///   ([`unpack::eo2_tail_range_raw`]).
    ///
    /// Both paths are **bit-identical** to `hopping` followed by
    /// `FermionField::xpay(a, b)` — the fused distributed M-hat changes
    /// memory traffic, never arithmetic.
    #[allow(clippy::too_many_arguments)]
    pub fn hopping_fused<R: Real + CommScalar, U: LinkSource<R>>(
        &self,
        out: &mut FermionField<R>,
        u: &U,
        psi: &FermionField<R>,
        p_out: Parity,
        comm: &mut Comm,
        team: &mut Team,
        prof: &Profiler,
        a: R,
        b: &FermionField<R>,
    ) {
        self.hopping_inner(out, u, psi, p_out, comm, team, prof, Some((a, b)));
    }

    #[allow(clippy::too_many_arguments)]
    fn hopping_inner<R: Real + CommScalar, U: LinkSource<R>>(
        &self,
        out: &mut FermionField<R>,
        u: &U,
        psi: &FermionField<R>,
        p_out: Parity,
        comm: &mut Comm,
        team: &mut Team,
        prof: &Profiler,
        tail: Option<(R, &FermionField<R>)>,
    ) {
        let plans = &self.plans[p_out.index()];
        let rank = comm.rank;
        let grid = self.geom.grid;
        let any_comm = self.comm_dirs.iter().any(|&c| c);

        // wire-format handshake: a precision desync across the rank
        // world surfaces here, BEFORE any payload is posted, as one
        // structured error naming every rank's format — instead of a
        // type panic (or a tag hang) in the middle of the exchange.
        // A single-rank world cannot desync with itself, so the forced
        // self-comm hot loops of the harness skip the collective.
        if any_comm && comm.nranks > 1 {
            if let Err(e) = validate_wire_format::<R>(comm, 1, &[true]) {
                panic!("{e}");
            }
        }

        // ---------------- EO1: pack send buffers --------------------
        let mut up_bufs: [Vec<R>; 4] = std::array::from_fn(|_| Vec::new());
        let mut down_bufs: [Vec<R>; 4] = std::array::from_fn(|_| Vec::new());
        for dir in 0..4 {
            if self.comm_dirs[dir] {
                up_bufs[dir] = vec![R::ZERO; plans.buffer_len(dir)];
                down_bufs[dir] = vec![R::ZERO; plans.buffer_len(dir)];
            }
        }
        {
            let up_ptrs: [SendPtr<R>; 4] =
                std::array::from_fn(|d| SendPtr(up_bufs[d].as_mut_ptr()));
            let down_ptrs: [SendPtr<R>; 4] =
                std::array::from_fn(|d| SendPtr(down_bufs[d].as_mut_ptr()));
            let n = self.nthreads;
            team.parallel(|tid| {
                prof.scope(tid, Phase::Eo1, || {
                    for dir in 0..4 {
                        if !self.comm_dirs[dir] {
                            continue;
                        }
                        // each direction's face loop is split evenly over
                        // the threads (paper §3.6: balanced EO1)
                        let count = plans.face_count[dir];
                        let (b, e) = chunk_range(count, tid, n);
                        if b == e {
                            continue;
                        }
                        // SAFETY: [b, e) is this thread's disjoint
                        // face-range shard of the send buffer.
                        let up = unsafe {
                            up_ptrs[dir].slice_mut(
                                b * pack::HALF_F32,
                                (e - b) * pack::HALF_F32,
                            )
                        };
                        pack_up_shifted(up, plans, dir, u, psi, b, e);
                        // SAFETY: same disjoint [b, e) shard of the
                        // down-face send buffer.
                        let down = unsafe {
                            down_ptrs[dir].slice_mut(
                                b * pack::HALF_F32,
                                (e - b) * pack::HALF_F32,
                            )
                        };
                        pack_down_shifted(down, plans, dir, psi, b, e);
                    }
                });
            });
        }

        // ---------------- post sends (master thread, FUNNELED) -------
        for dir in 0..4 {
            if !self.comm_dirs[dir] {
                continue;
            }
            let up_rank = grid.neighbor(rank, Dir::from_index(dir), 1);
            let down_rank = grid.neighbor(rank, Dir::from_index(dir), -1);
            comm.send(up_rank, tags::halo(dir, true, p_out), std::mem::take(&mut up_bufs[dir]));
            comm.send(
                down_rank,
                tags::halo(dir, false, p_out),
                std::mem::take(&mut down_bufs[dir]),
            );
        }

        // ---------------- bulk, overlapped with the wire -------------
        // With no communicated direction the bulk covers every site, so
        // a fused tail can ride the kernel store itself; with halo
        // imports pending it is applied in EO2 instead (bit-identical).
        let bulk_tail = if any_comm { None } else { tail };
        let eo2_tail = if any_comm { tail } else { None };
        {
            let out_ptr = SendPtr(out.data.as_mut_ptr());
            let ntiles = self.bulk.layout.ntiles();
            let tile_f32 = crate::lattice::SC2 * self.bulk.layout.vlen();
            let n = self.nthreads;
            let bulk = &self.bulk;
            team.parallel(|tid| {
                prof.scope(tid, Phase::Bulk, || {
                    let (b, e) = chunk_range(ntiles, tid, n);
                    if b == e {
                        return;
                    }
                    // SAFETY: disjoint tile ranges per thread.
                    let out_tiles = unsafe {
                        out_ptr.slice_mut(b * tile_f32, (e - b) * tile_f32)
                    };
                    match bulk_tail {
                        Some((a, bf)) => bulk.apply_tiles_fused(
                            out_tiles,
                            u,
                            &psi.data,
                            p_out,
                            b,
                            e,
                            StoreTail::Xpay { a, b: &bf.data },
                            None,
                        ),
                        None => bulk.apply_tiles(out_tiles, u, psi, p_out, b, e),
                    }
                });
            });
        }

        // ---------------- receive halos ------------------------------
        let mut bufs = RecvBuffers::<R>::default();
        prof.scope(0, Phase::CommWait, || {
            for dir in 0..4 {
                if !self.comm_dirs[dir] {
                    continue;
                }
                let up_rank = grid.neighbor(rank, Dir::from_index(dir), 1);
                let down_rank = grid.neighbor(rank, Dir::from_index(dir), -1);
                // my from_down buffer is the -d neighbor's upward export;
                // a transport fault degrades to a zero-filled face (the
                // error stays in the comm's poison slot for the solver
                // health guard — the sweep itself must finish so peers
                // aren't left hanging mid-exchange)
                bufs.from_down[dir] =
                    comm.recv_or_zero(down_rank, tags::halo(dir, true, p_out), plans.buffer_len(dir));
                // my from_up buffer is the +d neighbor's downward export
                bufs.from_up[dir] =
                    comm.recv_or_zero(up_rank, tags::halo(dir, false, p_out), plans.buffer_len(dir));
            }
        });

        // ---------------- EO2: unpack + boundary hopping -------------
        {
            let out_ptr = SendPtr(out.data.as_mut_ptr());
            let layout = self.bulk.layout;
            // a fused tail touches every site, so shard by site count;
            // without one the schedule's halo-cost partition applies
            let chunks = if eo2_tail.is_some() {
                &self.tail_chunks[p_out.index()]
            } else {
                &self.chunks[p_out.index()]
            };
            let bufs = &bufs;
            team.parallel(|tid| {
                prof.scope(tid, Phase::Eo2, || {
                    let (b, e) = chunks[tid];
                    if b == e {
                        return;
                    }
                    match eo2_tail {
                        // SAFETY: chunks[] partitions the boundary sites
                        // disjointly per tid, and the recv buffers are
                        // fully written before the merge region starts.
                        Some((a, bf)) => unsafe {
                            unpack::eo2_tail_range_raw(
                                out_ptr,
                                &layout,
                                plans,
                                bufs,
                                u,
                                b,
                                e,
                                a,
                                bf.data.as_ptr(),
                            );
                        },
                        // SAFETY: as above (disjoint boundary shard,
                        // quiesced recv buffers).
                        None => unsafe {
                            unpack::eo2_range_raw(out_ptr, &layout, plans, bufs, u, b, e);
                        },
                    }
                });
            });
        }
    }

    /// Batched distributed hopping: `out_r = H psi_r` (plus the optional
    /// fused per-RHS tail) for every *active* RHS of a block field, with
    /// the same EO1 -> post sends -> bulk ∥ wire -> wait -> EO2 pipeline
    /// as [`Self::hopping`] — but ONE message per direction/orientation
    /// carrying all active RHS, RHS-innermost on the wire. The message
    /// count per application is therefore independent of `nrhs`, while
    /// masked (converged) RHS drop out of the payload entirely.
    ///
    /// Per-RHS arithmetic (bulk kernel, EO1 pack, EO2 merge, tails) is
    /// byte-for-byte the single-RHS pipeline's, so each active RHS
    /// bit-matches [`Self::hopping`]/[`Self::hopping_fused`] on its
    /// demuxed field at any precision and rank count.
    ///
    /// Before the first send the ranks handshake on (precision, nrhs,
    /// active mask); a desync panics with the structured
    /// [`crate::comm::CommError`] message naming every rank's view (use
    /// [`validate_wire_format`] directly for a `Result`).
    #[allow(clippy::too_many_arguments)]
    pub fn hopping_multi<R: Real + CommScalar, U: LinkSource<R>>(
        &self,
        out: &mut MultiFermionField<R>,
        u: &U,
        psi: &MultiFermionField<R>,
        p_out: Parity,
        active: &[bool],
        comm: &mut Comm,
        team: &mut Team,
        prof: &Profiler,
        tail: MultiHopTail<R>,
    ) {
        let nrhs = psi.nrhs;
        debug_assert_eq!(out.nrhs, nrhs);
        debug_assert_eq!(active.len(), nrhs);
        let nact = active.iter().filter(|&&a| a).count();
        let plans = &self.plans[p_out.index()];
        let rank = comm.rank;
        let grid = self.geom.grid;
        let any_comm = self.comm_dirs.iter().any(|&c| c);

        if any_comm && comm.nranks > 1 {
            // wire-format handshake BEFORE any payload is posted (see
            // the module docs of `comm::world`): a rank-count, precision
            // or mask desync is a structured error here, never a
            // mid-exchange type panic or tag-mismatch hang (a 1-rank
            // world cannot desync with itself — skip the collective)
            if let Err(e) = validate_wire_format::<R>(comm, nrhs, active) {
                panic!("{e}");
            }
        }
        if nact == 0 {
            // uniform (validated) decision: nothing to hop, send nothing
            return;
        }
        let sig = wire_sig::<R>(nrhs, active);
        let n = self.nthreads;

        // ---------------- EO1: pack batched send buffers -------------
        let mut up_bufs: [Vec<R>; 4] = std::array::from_fn(|_| Vec::new());
        let mut down_bufs: [Vec<R>; 4] = std::array::from_fn(|_| Vec::new());
        for dir in 0..4 {
            if self.comm_dirs[dir] {
                up_bufs[dir] = vec![R::ZERO; plans.buffer_len_multi(dir, nact)];
                down_bufs[dir] = vec![R::ZERO; plans.buffer_len_multi(dir, nact)];
            }
        }
        {
            let up_ptrs: [SendPtr<R>; 4] =
                std::array::from_fn(|d| SendPtr(up_bufs[d].as_mut_ptr()));
            let down_ptrs: [SendPtr<R>; 4] =
                std::array::from_fn(|d| SendPtr(down_bufs[d].as_mut_ptr()));
            let site_reals = nact * pack::HALF_F32;
            team.parallel(|tid| {
                prof.scope(tid, Phase::Eo1, || {
                    for dir in 0..4 {
                        if !self.comm_dirs[dir] {
                            continue;
                        }
                        let count = plans.face_count[dir];
                        let (b, e) = chunk_range(count, tid, n);
                        if b == e {
                            continue;
                        }
                        // SAFETY: [b, e) is this thread's disjoint
                        // face-range shard of the batched send buffer.
                        let up = unsafe {
                            up_ptrs[dir].slice_mut(b * site_reals, (e - b) * site_reals)
                        };
                        pack::pack_up_multi_rel(up, plans, dir, u, psi, active, b, e);
                        // SAFETY: same disjoint [b, e) shard of the
                        // batched down-face send buffer.
                        let down = unsafe {
                            down_ptrs[dir]
                                .slice_mut(b * site_reals, (e - b) * site_reals)
                        };
                        pack::pack_down_multi_rel(down, plans, dir, psi, active, b, e);
                    }
                });
            });
        }

        // ---------------- post sends (master thread, FUNNELED) -------
        // one message per direction per orientation, whatever nrhs is
        for dir in 0..4 {
            if !self.comm_dirs[dir] {
                continue;
            }
            let up_rank = grid.neighbor(rank, Dir::from_index(dir), 1);
            let down_rank = grid.neighbor(rank, Dir::from_index(dir), -1);
            comm.send(
                up_rank,
                tags::halo_batched(dir, true, p_out, sig),
                std::mem::take(&mut up_bufs[dir]),
            );
            comm.send(
                down_rank,
                tags::halo_batched(dir, false, p_out, sig),
                std::mem::take(&mut down_bufs[dir]),
            );
        }

        // ---------------- bulk, overlapped with the wire -------------
        {
            let out_ptr = SendPtr(out.data.as_mut_ptr());
            let ntiles = self.bulk.layout.ntiles();
            let sub_reals = nrhs * crate::lattice::SC2 * self.bulk.layout.vlen();
            let bulk = &self.bulk;
            team.parallel(|tid| {
                prof.scope(tid, Phase::Bulk, || {
                    let (b, e) = chunk_range(ntiles, tid, n);
                    if b == e {
                        return;
                    }
                    // SAFETY: disjoint tile ranges per thread.
                    let out_tiles = unsafe {
                        out_ptr.slice_mut(b * sub_reals, (e - b) * sub_reals)
                    };
                    // without communicating directions the bulk covers
                    // every site, so the tail rides the kernel store;
                    // otherwise it moves to the EO2 merge (bit-identical)
                    let store = if any_comm {
                        MultiStoreTail::Assign
                    } else {
                        match tail {
                            MultiHopTail::Assign => MultiStoreTail::Assign,
                            MultiHopTail::Xpay { a, b: bf } => {
                                MultiStoreTail::Xpay { a, b: &bf.data }
                            }
                            MultiHopTail::Gamma5Xpay { a, b: bf } => {
                                MultiStoreTail::Gamma5Xpay { a, b: &bf.data }
                            }
                        }
                    };
                    bulk.apply_tiles_multi(
                        out_tiles, u, &psi.data, p_out, b, e, nrhs, active, store,
                        None,
                    );
                });
            });
        }

        // ---------------- receive batched halos ----------------------
        let mut bufs = RecvBuffers::<R>::default();
        prof.scope(0, Phase::CommWait, || {
            for dir in 0..4 {
                if !self.comm_dirs[dir] {
                    continue;
                }
                let up_rank = grid.neighbor(rank, Dir::from_index(dir), 1);
                let down_rank = grid.neighbor(rank, Dir::from_index(dir), -1);
                // a transport fault degrades to a zero-filled batched
                // face; the poison slot carries the error to the solver
                // health guard after the sweep completes
                bufs.from_down[dir] = comm.recv_or_zero(
                    down_rank,
                    tags::halo_batched(dir, true, p_out, sig),
                    plans.buffer_len_multi(dir, nact),
                );
                bufs.from_up[dir] = comm.recv_or_zero(
                    up_rank,
                    tags::halo_batched(dir, false, p_out, sig),
                    plans.buffer_len_multi(dir, nact),
                );
            }
        });

        // ---------------- EO2: batched unpack + boundary hopping -----
        // (without communicating directions the tail already rode the
        // bulk store and there is nothing to merge)
        if any_comm {
            let out_ptr = SendPtr(out.data.as_mut_ptr());
            let layout = self.bulk.layout;
            let eo2_tail = match tail {
                MultiHopTail::Assign => MultiEo2Tail::None,
                MultiHopTail::Xpay { a, b: bf } => MultiEo2Tail::Xpay {
                    a,
                    b: SendPtr(bf.data.as_ptr() as *mut R),
                },
                MultiHopTail::Gamma5Xpay { a, b: bf } => MultiEo2Tail::Gamma5Xpay {
                    a,
                    b: SendPtr(bf.data.as_ptr() as *mut R),
                },
            };
            // a fused tail touches every site: shard by site count
            let chunks = if matches!(eo2_tail, MultiEo2Tail::None) {
                &self.chunks[p_out.index()]
            } else {
                &self.tail_chunks[p_out.index()]
            };
            let bufs = &bufs;
            team.parallel(|tid| {
                prof.scope(tid, Phase::Eo2, || {
                    let (b, e) = chunks[tid];
                    if b == e {
                        return;
                    }
                    // SAFETY: chunks[] partitions the boundary sites
                    // disjointly per tid, and the recv buffers are fully
                    // written before the merge region starts.
                    unsafe {
                        unpack::eo2_multi_range_raw(
                            out_ptr, &layout, plans, bufs, u, nrhs, active, b, e,
                            eo2_tail,
                        );
                    }
                });
            });
        }
    }
}

/// EO1 pack helpers re-exported with the profiling-friendly names used by
/// the driver (they operate on buffer *sub-slices* starting at site b).
fn pack_up_shifted<R: Real, U: LinkSource<R>>(
    buf: &mut [R],
    plans: &HaloPlans,
    dir: usize,
    u: &U,
    psi: &FermionField<R>,
    b: usize,
    e: usize,
) {
    // pack::pack_up_range indexes the buffer absolutely; shift into a view
    pack::pack_up_range_rel(buf, plans, dir, u, psi, b, e);
}

fn pack_down_shifted<R: Real>(
    buf: &mut [R],
    plans: &HaloPlans,
    dir: usize,
    psi: &FermionField<R>,
    b: usize,
    e: usize,
) {
    pack::pack_down_range_rel(buf, plans, dir, psi, b, e);
}
