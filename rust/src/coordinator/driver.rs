//! The distributed even-odd hopping driver: EO1 -> post sends -> bulk
//! (overlapped with the wire) -> wait -> EO2, with every phase charged to
//! the FAPP-analog profiler. This is the per-rank pipeline of §3.5-3.6.

use crate::algebra::Real;
use crate::comm::halo::HaloPlans;
use crate::comm::unpack::RecvBuffers;
use crate::comm::{balance, pack, unpack, Comm, CommScalar};
use crate::dslash::{HoppingEo, LinkSource, StoreTail, WrapMode};
use crate::field::FermionField;
use crate::lattice::{Dir, Geometry, Parity};

use super::profiler::{Phase, Profiler};
use super::team::{chunk_range, SendPtr, Team};

/// EO2 thread-partition policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Eo2Schedule {
    /// equal site counts (the paper's current scheme; Fig. 9 imbalance)
    Uniform,
    /// cost-weighted static partition (the paper's proposed future work)
    Balanced,
}

/// Message tag: direction, orientation, output parity.
fn tag(dir: usize, upward: bool, p_out: Parity) -> u64 {
    ((p_out.index() as u64) << 8) | ((dir as u64) << 1) | u64::from(upward)
}

/// Distributed even-odd hopping operator for one rank.
pub struct DistHopping {
    pub geom: Geometry,
    pub comm_dirs: [bool; 4],
    bulk: HoppingEo,
    plans: [HaloPlans; 2],
    pub schedule: Eo2Schedule,
    /// cached balanced chunks per parity (computed on demand)
    chunks: [Vec<(usize, usize)>; 2],
    /// site-uniform chunks per parity, used when a fused tail makes the
    /// EO2 pass cost uniform per site (the balanced chunks weight halo
    /// imports only and would serialize the tail on one thread)
    tail_chunks: [Vec<(usize, usize)>; 2],
    nthreads: usize,
}

impl DistHopping {
    /// `force_comm` routes even self-neighbor directions through the
    /// communication path, as the paper does in all its measurements.
    pub fn new(
        geom: &Geometry,
        force_comm: bool,
        nthreads: usize,
        schedule: Eo2Schedule,
    ) -> DistHopping {
        let comm_dirs =
            std::array::from_fn(|d| force_comm || geom.grid.0[d] > 1);
        let wrap = std::array::from_fn(|d| {
            if comm_dirs[d] {
                WrapMode::SkipBoundary
            } else {
                WrapMode::Periodic
            }
        });
        let plans = [
            HaloPlans::new(geom, Parity::Even, comm_dirs),
            HaloPlans::new(geom, Parity::Odd, comm_dirs),
        ];
        let chunks = std::array::from_fn(|p| match schedule {
            Eo2Schedule::Uniform => balance::uniform_chunks(plans[p].nsites, nthreads),
            Eo2Schedule::Balanced => balance::balanced_chunks(&plans[p], nthreads),
        });
        let tail_chunks =
            std::array::from_fn(|p| balance::uniform_chunks(plans[p].nsites, nthreads));
        DistHopping {
            geom: *geom,
            comm_dirs,
            bulk: HoppingEo::with_wrap(geom, wrap),
            plans,
            schedule,
            chunks,
            tail_chunks,
            nthreads,
        }
    }

    pub fn plans(&self, p_out: Parity) -> &HaloPlans {
        &self.plans[p_out.index()]
    }

    /// out = H_{p_out <- 1-p_out} psi across the rank world. Generic over
    /// the field precision (halo buffers and the wire payload follow `R`)
    /// and the [`LinkSource`]: the bulk kernel streams full or two-row
    /// compressed link tiles, and the EO1 pack / EO2 merge fetch their
    /// per-site links from the same source. Only spinor half-halos ever
    /// hit the wire, so compression changes no message.
    pub fn hopping<R: Real + CommScalar, U: LinkSource<R>>(
        &self,
        out: &mut FermionField<R>,
        u: &U,
        psi: &FermionField<R>,
        p_out: Parity,
        comm: &mut Comm,
        team: &mut Team,
        prof: &Profiler,
    ) {
        self.hopping_inner(out, u, psi, p_out, comm, team, prof, None);
    }

    /// [`Self::hopping`] with the M-hat xpay tail `out = a * (H psi) + b`
    /// fused into the pipeline instead of running as a separate
    /// full-field sweep afterwards (ROADMAP PR 2 follow-up):
    ///
    /// * when no direction communicates, the bulk kernel covers every
    ///   site and stores through [`StoreTail::Xpay`] — zero extra passes;
    /// * otherwise the bulk stores plain and EO2 applies the tail per
    ///   site in the same pass that merges the halo contributions
    ///   ([`unpack::eo2_tail_range_raw`]).
    ///
    /// Both paths are **bit-identical** to `hopping` followed by
    /// `FermionField::xpay(a, b)` — the fused distributed M-hat changes
    /// memory traffic, never arithmetic.
    #[allow(clippy::too_many_arguments)]
    pub fn hopping_fused<R: Real + CommScalar, U: LinkSource<R>>(
        &self,
        out: &mut FermionField<R>,
        u: &U,
        psi: &FermionField<R>,
        p_out: Parity,
        comm: &mut Comm,
        team: &mut Team,
        prof: &Profiler,
        a: R,
        b: &FermionField<R>,
    ) {
        self.hopping_inner(out, u, psi, p_out, comm, team, prof, Some((a, b)));
    }

    #[allow(clippy::too_many_arguments)]
    fn hopping_inner<R: Real + CommScalar, U: LinkSource<R>>(
        &self,
        out: &mut FermionField<R>,
        u: &U,
        psi: &FermionField<R>,
        p_out: Parity,
        comm: &mut Comm,
        team: &mut Team,
        prof: &Profiler,
        tail: Option<(R, &FermionField<R>)>,
    ) {
        let plans = &self.plans[p_out.index()];
        let rank = comm.rank;
        let grid = self.geom.grid;

        // ---------------- EO1: pack send buffers --------------------
        let mut up_bufs: [Vec<R>; 4] = std::array::from_fn(|_| Vec::new());
        let mut down_bufs: [Vec<R>; 4] = std::array::from_fn(|_| Vec::new());
        for dir in 0..4 {
            if self.comm_dirs[dir] {
                up_bufs[dir] = vec![R::ZERO; plans.buffer_len(dir)];
                down_bufs[dir] = vec![R::ZERO; plans.buffer_len(dir)];
            }
        }
        {
            let up_ptrs: [SendPtr<R>; 4] =
                std::array::from_fn(|d| SendPtr(up_bufs[d].as_mut_ptr()));
            let down_ptrs: [SendPtr<R>; 4] =
                std::array::from_fn(|d| SendPtr(down_bufs[d].as_mut_ptr()));
            let n = self.nthreads;
            team.parallel(|tid| {
                prof.scope(tid, Phase::Eo1, || {
                    for dir in 0..4 {
                        if !self.comm_dirs[dir] {
                            continue;
                        }
                        // each direction's face loop is split evenly over
                        // the threads (paper §3.6: balanced EO1)
                        let count = plans.face_count[dir];
                        let (b, e) = chunk_range(count, tid, n);
                        if b == e {
                            continue;
                        }
                        let up = unsafe {
                            up_ptrs[dir].slice_mut(
                                b * pack::HALF_F32,
                                (e - b) * pack::HALF_F32,
                            )
                        };
                        pack_up_shifted(up, plans, dir, u, psi, b, e);
                        let down = unsafe {
                            down_ptrs[dir].slice_mut(
                                b * pack::HALF_F32,
                                (e - b) * pack::HALF_F32,
                            )
                        };
                        pack_down_shifted(down, plans, dir, psi, b, e);
                    }
                });
            });
        }

        // ---------------- post sends (master thread, FUNNELED) -------
        for dir in 0..4 {
            if !self.comm_dirs[dir] {
                continue;
            }
            let up_rank = grid.neighbor(rank, Dir::from_index(dir), 1);
            let down_rank = grid.neighbor(rank, Dir::from_index(dir), -1);
            comm.send(up_rank, tag(dir, true, p_out), std::mem::take(&mut up_bufs[dir]));
            comm.send(
                down_rank,
                tag(dir, false, p_out),
                std::mem::take(&mut down_bufs[dir]),
            );
        }

        // ---------------- bulk, overlapped with the wire -------------
        // With no communicated direction the bulk covers every site, so
        // a fused tail can ride the kernel store itself; with halo
        // imports pending it is applied in EO2 instead (bit-identical).
        let any_comm = self.comm_dirs.iter().any(|&c| c);
        let bulk_tail = if any_comm { None } else { tail };
        let eo2_tail = if any_comm { tail } else { None };
        {
            let out_ptr = SendPtr(out.data.as_mut_ptr());
            let ntiles = self.bulk.layout.ntiles();
            let tile_f32 = crate::lattice::SC2 * self.bulk.layout.vlen();
            let n = self.nthreads;
            let bulk = &self.bulk;
            team.parallel(|tid| {
                prof.scope(tid, Phase::Bulk, || {
                    let (b, e) = chunk_range(ntiles, tid, n);
                    if b == e {
                        return;
                    }
                    // disjoint tile ranges per thread
                    let out_tiles = unsafe {
                        out_ptr.slice_mut(b * tile_f32, (e - b) * tile_f32)
                    };
                    match bulk_tail {
                        Some((a, bf)) => bulk.apply_tiles_fused(
                            out_tiles,
                            u,
                            &psi.data,
                            p_out,
                            b,
                            e,
                            StoreTail::Xpay { a, b: &bf.data },
                            None,
                        ),
                        None => bulk.apply_tiles(out_tiles, u, psi, p_out, b, e),
                    }
                });
            });
        }

        // ---------------- receive halos ------------------------------
        let mut bufs = RecvBuffers::<R>::default();
        prof.scope(0, Phase::CommWait, || {
            for dir in 0..4 {
                if !self.comm_dirs[dir] {
                    continue;
                }
                let up_rank = grid.neighbor(rank, Dir::from_index(dir), 1);
                let down_rank = grid.neighbor(rank, Dir::from_index(dir), -1);
                // my from_down buffer is the -d neighbor's upward export
                bufs.from_down[dir] = comm.recv(down_rank, tag(dir, true, p_out));
                // my from_up buffer is the +d neighbor's downward export
                bufs.from_up[dir] = comm.recv(up_rank, tag(dir, false, p_out));
            }
        });

        // ---------------- EO2: unpack + boundary hopping -------------
        {
            let out_ptr = SendPtr(out.data.as_mut_ptr());
            let layout = self.bulk.layout;
            // a fused tail touches every site, so shard by site count;
            // without one the schedule's halo-cost partition applies
            let chunks = if eo2_tail.is_some() {
                &self.tail_chunks[p_out.index()]
            } else {
                &self.chunks[p_out.index()]
            };
            let bufs = &bufs;
            team.parallel(|tid| {
                prof.scope(tid, Phase::Eo2, || {
                    let (b, e) = chunks[tid];
                    if b == e {
                        return;
                    }
                    match eo2_tail {
                        Some((a, bf)) => unsafe {
                            unpack::eo2_tail_range_raw(
                                out_ptr,
                                &layout,
                                plans,
                                bufs,
                                u,
                                b,
                                e,
                                a,
                                bf.data.as_ptr(),
                            );
                        },
                        None => unsafe {
                            unpack::eo2_range_raw(out_ptr, &layout, plans, bufs, u, b, e);
                        },
                    }
                });
            });
        }
    }
}

/// EO1 pack helpers re-exported with the profiling-friendly names used by
/// the driver (they operate on buffer *sub-slices* starting at site b).
fn pack_up_shifted<R: Real, U: LinkSource<R>>(
    buf: &mut [R],
    plans: &HaloPlans,
    dir: usize,
    u: &U,
    psi: &FermionField<R>,
    b: usize,
    e: usize,
) {
    // pack::pack_up_range indexes the buffer absolutely; shift into a view
    pack::pack_up_range_rel(buf, plans, dir, u, psi, b, e);
}

fn pack_down_shifted<R: Real>(
    buf: &mut [R],
    plans: &HaloPlans,
    dir: usize,
    psi: &FermionField<R>,
    b: usize,
    e: usize,
) {
    pack::pack_down_range_rel(buf, plans, dir, psi, b, e);
}
