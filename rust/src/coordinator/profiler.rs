//! FAPP-analog profiler: per-thread, per-phase cycle (time) accounting.
//!
//! The paper uses the Fujitsu advanced performance profiler to produce
//! the stacked per-thread execution-time bars of Figs. 8 and 9. This
//! profiler collects the same series for our kernels: each thread
//! accumulates wall time into phase buckets; the harness renders the
//! per-thread stacks and the imbalance statistics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::perf::telemetry::Tracer;

/// Execution phases of one distributed hopping application.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// EO1: pack send buffers (paper Fig. 9 top)
    Eo1 = 0,
    /// bulk stencil (paper Fig. 8)
    Bulk = 1,
    /// waiting for halo messages
    CommWait = 2,
    /// EO2: unpack + boundary hopping (paper Fig. 9 bottom)
    Eo2 = 3,
    /// barrier / join time
    Barrier = 4,
    /// solver BLAS sweeps (axpy/xpay/dot tails of the fused CG pipeline)
    Blas = 5,
    /// time discarded by a health-guard restart (the failed attempt's
    /// phase buckets are folded here so post-restart bars stay clean)
    Restart = 6,
    /// checkpoint writes (encode + fsync + commit collective)
    Checkpoint = 7,
}

impl Phase {
    pub const ALL: [Phase; 8] = [
        Phase::Eo1,
        Phase::Bulk,
        Phase::CommWait,
        Phase::Eo2,
        Phase::Barrier,
        Phase::Blas,
        Phase::Restart,
        Phase::Checkpoint,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Phase::Eo1 => "EO1(pack)",
            Phase::Bulk => "bulk",
            Phase::CommWait => "comm-wait",
            Phase::Eo2 => "EO2(unpack)",
            Phase::Barrier => "barrier",
            Phase::Blas => "blas",
            Phase::Restart => "restart",
            Phase::Checkpoint => "checkpoint",
        }
    }
}

const NPHASE: usize = 8;

/// Lock-free per-thread x per-phase nanosecond accumulators, with an
/// optional span tracer riding every [`Profiler::scope`] call: when a
/// [`Tracer`] is attached each timed scope also records a
/// `(phase, rank, thread, iter, t_start, t_end)` span, at the cost of
/// one extra clock read — with no tracer the path is unchanged.
#[derive(Debug)]
pub struct Profiler {
    nthreads: usize,
    nanos: Vec<AtomicU64>,
    /// per-thread flop counters (for per-core Flops as in Fig. 9's check)
    flops: Vec<AtomicU64>,
    tracer: Option<Arc<Tracer>>,
}

impl Profiler {
    pub fn new(nthreads: usize) -> Profiler {
        Profiler {
            nthreads,
            nanos: (0..nthreads * NPHASE).map(|_| AtomicU64::new(0)).collect(),
            flops: (0..nthreads).map(|_| AtomicU64::new(0)).collect(),
            tracer: None,
        }
    }

    /// A profiler that also streams spans into `tracer` (built with the
    /// same thread count).
    pub fn with_tracer(nthreads: usize, tracer: Arc<Tracer>) -> Profiler {
        Profiler {
            tracer: Some(tracer),
            ..Profiler::new(nthreads)
        }
    }

    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.tracer.as_ref()
    }

    /// Tag subsequent spans with the solver iteration (no-op untraced).
    pub fn set_iter(&self, iter: usize) {
        if let Some(t) = &self.tracer {
            t.set_iter(iter);
        }
    }

    pub fn nthreads(&self) -> usize {
        self.nthreads
    }

    /// Time `f` and charge it to (tid, phase).
    #[inline]
    pub fn scope<R>(&self, tid: usize, phase: Phase, f: impl FnOnce() -> R) -> R {
        let span_start = self.tracer.as_ref().map(|t| t.now_ns());
        let start = Instant::now();
        let r = f();
        let nanos = start.elapsed().as_nanos() as u64;
        self.add(tid, phase, nanos);
        if let (Some(t), Some(s0)) = (self.tracer.as_deref(), span_start) {
            t.record(tid, phase as u8, s0, s0 + nanos, 0, 0);
        }
        r
    }

    #[inline]
    pub fn add(&self, tid: usize, phase: Phase, nanos: u64) {
        self.nanos[tid * NPHASE + phase as usize].fetch_add(nanos, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_flops(&self, tid: usize, flops: u64) {
        self.flops[tid].fetch_add(flops, Ordering::Relaxed);
    }

    pub fn seconds(&self, tid: usize, phase: Phase) -> f64 {
        self.nanos[tid * NPHASE + phase as usize].load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn thread_flops(&self, tid: usize) -> u64 {
        self.flops[tid].load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        for a in &self.nanos {
            a.store(0, Ordering::Relaxed);
        }
        for a in &self.flops {
            a.store(0, Ordering::Relaxed);
        }
    }

    /// Health-guard restart boundary: fold every per-thread phase bucket
    /// into [`Phase::Restart`] and zero the flop counters. The discarded
    /// attempt's wall time stays visible in the bars (as `restart`)
    /// while the per-phase breakdown and Fig. 9-style flops/core of the
    /// attempt that eventually converges start clean.
    pub fn restart_reset(&self) {
        for tid in 0..self.nthreads {
            let mut discarded = 0u64;
            for p in 0..NPHASE {
                if p == Phase::Restart as usize {
                    continue;
                }
                discarded += self.nanos[tid * NPHASE + p].swap(0, Ordering::Relaxed);
            }
            self.nanos[tid * NPHASE + Phase::Restart as usize]
                .fetch_add(discarded, Ordering::Relaxed);
            self.flops[tid].store(0, Ordering::Relaxed);
        }
    }

    /// Snapshot: `report[tid][phase]` in seconds.
    pub fn snapshot(&self) -> Report {
        let mut times = Vec::with_capacity(self.nthreads);
        for tid in 0..self.nthreads {
            times.push(
                Phase::ALL
                    .iter()
                    .map(|&p| self.seconds(tid, p))
                    .collect::<Vec<f64>>(),
            );
        }
        Report {
            times,
            flops: (0..self.nthreads).map(|t| self.thread_flops(t)).collect(),
        }
    }
}

/// A profiling snapshot for rendering / assertions.
#[derive(Clone, Debug)]
pub struct Report {
    /// [tid][phase] seconds
    pub times: Vec<Vec<f64>>,
    pub flops: Vec<u64>,
}

impl Report {
    /// Number of threads the snapshot covers. Carried explicitly into
    /// the JSON output because `imbalance` alone cannot distinguish a
    /// single-thread report (max/mean trivially 1.0) from a genuinely
    /// balanced many-thread one.
    pub fn nthreads(&self) -> usize {
        self.times.len()
    }

    /// Total time of one phase across threads.
    pub fn phase_total(&self, phase: Phase) -> f64 {
        self.times.iter().map(|t| t[phase as usize]).sum()
    }

    /// max/mean imbalance of a phase across threads (1.0 = balanced).
    pub fn imbalance(&self, phase: Phase) -> f64 {
        let vals: Vec<f64> = self.times.iter().map(|t| t[phase as usize]).collect();
        let max = vals.iter().cloned().fold(0.0, f64::max);
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Render the Fig. 8/9-style per-thread stacked bars.
    pub fn render(&self, title: &str) -> String {
        let labels: Vec<String> = (0..self.times.len())
            .map(|t| format!("thread {t:>2}"))
            .collect();
        let segments: Vec<String> =
            Phase::ALL.iter().map(|p| p.label().to_string()).collect();
        crate::util::tables::stacked_bars(title, &labels, &segments, &self.times, 60)
    }

    /// Machine-readable profile (the `profile.json` of `lqcd solve
    /// --profile`): thread count, per-phase totals + max/mean imbalance,
    /// per-thread phase seconds and flops. Emitted through
    /// [`crate::util::json::JsonWriter`]: deterministic key order, the
    /// repo-wide `{:.9e}` float convention.
    pub fn to_json(&self) -> String {
        let mut w = crate::util::json::JsonWriter::new();
        w.obj_begin();
        w.key("threads");
        w.uint(self.nthreads() as u64);
        w.key("phases");
        w.obj_begin();
        for &p in Phase::ALL.iter() {
            w.key(p.label());
            w.obj_begin();
            w.key("seconds");
            w.num(self.phase_total(p));
            w.key("imbalance");
            w.num(self.imbalance(p));
            w.obj_end();
        }
        w.obj_end();
        w.key("per_thread");
        w.arr_begin();
        for tid in 0..self.nthreads() {
            w.obj_begin();
            w.key("tid");
            w.uint(tid as u64);
            w.key("seconds");
            w.arr_begin();
            for &t in &self.times[tid] {
                w.num(t);
            }
            w.arr_end();
            w.key("flops");
            w.uint(self.flops[tid]);
            w.obj_end();
        }
        w.arr_end();
        w.obj_end();
        w.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_per_thread_and_phase() {
        let p = Profiler::new(3);
        p.add(0, Phase::Bulk, 1_000_000);
        p.add(0, Phase::Bulk, 500_000);
        p.add(2, Phase::Eo2, 2_000_000);
        assert!((p.seconds(0, Phase::Bulk) - 1.5e-3).abs() < 1e-12);
        assert_eq!(p.seconds(1, Phase::Bulk), 0.0);
        assert!((p.seconds(2, Phase::Eo2) - 2e-3).abs() < 1e-12);
    }

    #[test]
    fn scope_times_work() {
        let p = Profiler::new(1);
        let r = p.scope(0, Phase::Eo1, || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(r, 42);
        assert!(p.seconds(0, Phase::Eo1) >= 4e-3);
    }

    #[test]
    fn report_imbalance() {
        let p = Profiler::new(4);
        for tid in 0..4 {
            p.add(tid, Phase::Eo2, 1_000_000);
        }
        p.add(3, Phase::Eo2, 3_000_000); // thread 3 is 4x the others
        let r = p.snapshot();
        let imb = r.imbalance(Phase::Eo2);
        assert!(imb > 2.0, "imbalance {imb}");
        assert!((r.phase_total(Phase::Eo2) - 7e-3).abs() < 1e-9);
    }

    #[test]
    fn reset_clears() {
        let p = Profiler::new(2);
        p.add(1, Phase::Barrier, 7);
        p.add_flops(1, 99);
        p.reset();
        assert_eq!(p.seconds(1, Phase::Barrier), 0.0);
        assert_eq!(p.thread_flops(1), 0);
    }

    #[test]
    fn restart_reset_folds_into_restart_bucket() {
        let p = Profiler::new(2);
        p.add(0, Phase::Bulk, 3_000_000);
        p.add(0, Phase::CommWait, 1_000_000);
        p.add(1, Phase::Blas, 2_000_000);
        p.add_flops(0, 777);
        p.restart_reset();
        // phase buckets are clean, the discarded time is attributed
        assert_eq!(p.seconds(0, Phase::Bulk), 0.0);
        assert_eq!(p.seconds(0, Phase::CommWait), 0.0);
        assert!((p.seconds(0, Phase::Restart) - 4e-3).abs() < 1e-12);
        assert!((p.seconds(1, Phase::Restart) - 2e-3).abs() < 1e-12);
        assert_eq!(p.thread_flops(0), 0, "failed attempt's flops discarded");
        // a second restart accumulates on top of the first
        p.add(0, Phase::Bulk, 500_000);
        p.restart_reset();
        assert!((p.seconds(0, Phase::Restart) - 4.5e-3).abs() < 1e-12);
    }

    #[test]
    fn scope_with_tracer_records_spans() {
        use crate::perf::telemetry::Tracer;
        let tracer = std::sync::Arc::new(Tracer::new(1, 16, 0));
        let p = Profiler::with_tracer(1, tracer.clone());
        p.set_iter(5);
        let r = p.scope(0, Phase::Bulk, || 7);
        assert_eq!(r, 7);
        let data = tracer.drain();
        assert_eq!(data.spans.len(), 1);
        assert_eq!(data.spans[0].code, Phase::Bulk as u8);
        assert_eq!(data.spans[0].iter, 5);
        // the span and the aggregate bucket agree on the duration
        let span_secs = data.spans[0].seconds();
        assert!((span_secs - p.seconds(0, Phase::Bulk)).abs() < 1e-12);
    }

    #[test]
    fn render_contains_threads_and_legend() {
        let p = Profiler::new(2);
        p.add(0, Phase::Bulk, 1000);
        p.add(1, Phase::Eo1, 500);
        let s = p.snapshot().render("fig");
        assert!(s.contains("thread  0"));
        assert!(s.contains("legend:"));
        assert!(s.contains("EO2"));
    }

    #[test]
    fn json_reports_thread_count_and_parses() {
        let p = Profiler::new(2);
        p.add(0, Phase::Bulk, 1_000_000);
        p.add(1, Phase::Blas, 500_000);
        p.add_flops(0, 1234);
        let text = p.snapshot().to_json();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(j.get("threads").unwrap().as_usize(), Some(2));
        let phases = j.get("phases").unwrap();
        let bulk_secs = phases
            .get("bulk")
            .unwrap()
            .get("seconds")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(bulk_secs > 0.0);
        let per = j.get("per_thread").unwrap().as_arr().unwrap();
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].get("flops").unwrap().as_usize(), Some(1234));
        assert_eq!(
            per[1].get("seconds").unwrap().as_arr().unwrap().len(),
            Phase::ALL.len()
        );
    }
}
