//! FAPP-analog profiler: per-thread, per-phase cycle (time) accounting.
//!
//! The paper uses the Fujitsu advanced performance profiler to produce
//! the stacked per-thread execution-time bars of Figs. 8 and 9. This
//! profiler collects the same series for our kernels: each thread
//! accumulates wall time into phase buckets; the harness renders the
//! per-thread stacks and the imbalance statistics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Execution phases of one distributed hopping application.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// EO1: pack send buffers (paper Fig. 9 top)
    Eo1 = 0,
    /// bulk stencil (paper Fig. 8)
    Bulk = 1,
    /// waiting for halo messages
    CommWait = 2,
    /// EO2: unpack + boundary hopping (paper Fig. 9 bottom)
    Eo2 = 3,
    /// barrier / join time
    Barrier = 4,
    /// solver BLAS sweeps (axpy/xpay/dot tails of the fused CG pipeline)
    Blas = 5,
}

impl Phase {
    pub const ALL: [Phase; 6] = [
        Phase::Eo1,
        Phase::Bulk,
        Phase::CommWait,
        Phase::Eo2,
        Phase::Barrier,
        Phase::Blas,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Phase::Eo1 => "EO1(pack)",
            Phase::Bulk => "bulk",
            Phase::CommWait => "comm-wait",
            Phase::Eo2 => "EO2(unpack)",
            Phase::Barrier => "barrier",
            Phase::Blas => "blas",
        }
    }
}

const NPHASE: usize = 6;

/// Lock-free per-thread x per-phase nanosecond accumulators.
#[derive(Debug)]
pub struct Profiler {
    nthreads: usize,
    nanos: Vec<AtomicU64>,
    /// per-thread flop counters (for per-core Flops as in Fig. 9's check)
    flops: Vec<AtomicU64>,
}

impl Profiler {
    pub fn new(nthreads: usize) -> Profiler {
        Profiler {
            nthreads,
            nanos: (0..nthreads * NPHASE).map(|_| AtomicU64::new(0)).collect(),
            flops: (0..nthreads).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn nthreads(&self) -> usize {
        self.nthreads
    }

    /// Time `f` and charge it to (tid, phase).
    #[inline]
    pub fn scope<R>(&self, tid: usize, phase: Phase, f: impl FnOnce() -> R) -> R {
        let start = Instant::now();
        let r = f();
        self.add(tid, phase, start.elapsed().as_nanos() as u64);
        r
    }

    #[inline]
    pub fn add(&self, tid: usize, phase: Phase, nanos: u64) {
        self.nanos[tid * NPHASE + phase as usize].fetch_add(nanos, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_flops(&self, tid: usize, flops: u64) {
        self.flops[tid].fetch_add(flops, Ordering::Relaxed);
    }

    pub fn seconds(&self, tid: usize, phase: Phase) -> f64 {
        self.nanos[tid * NPHASE + phase as usize].load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn thread_flops(&self, tid: usize) -> u64 {
        self.flops[tid].load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        for a in &self.nanos {
            a.store(0, Ordering::Relaxed);
        }
        for a in &self.flops {
            a.store(0, Ordering::Relaxed);
        }
    }

    /// Snapshot: `report[tid][phase]` in seconds.
    pub fn snapshot(&self) -> Report {
        let mut times = Vec::with_capacity(self.nthreads);
        for tid in 0..self.nthreads {
            times.push(
                Phase::ALL
                    .iter()
                    .map(|&p| self.seconds(tid, p))
                    .collect::<Vec<f64>>(),
            );
        }
        Report {
            times,
            flops: (0..self.nthreads).map(|t| self.thread_flops(t)).collect(),
        }
    }
}

/// A profiling snapshot for rendering / assertions.
#[derive(Clone, Debug)]
pub struct Report {
    /// [tid][phase] seconds
    pub times: Vec<Vec<f64>>,
    pub flops: Vec<u64>,
}

impl Report {
    /// Number of threads the snapshot covers. Carried explicitly into
    /// the JSON output because `imbalance` alone cannot distinguish a
    /// single-thread report (max/mean trivially 1.0) from a genuinely
    /// balanced many-thread one.
    pub fn nthreads(&self) -> usize {
        self.times.len()
    }

    /// Total time of one phase across threads.
    pub fn phase_total(&self, phase: Phase) -> f64 {
        self.times.iter().map(|t| t[phase as usize]).sum()
    }

    /// max/mean imbalance of a phase across threads (1.0 = balanced).
    pub fn imbalance(&self, phase: Phase) -> f64 {
        let vals: Vec<f64> = self.times.iter().map(|t| t[phase as usize]).collect();
        let max = vals.iter().cloned().fold(0.0, f64::max);
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Render the Fig. 8/9-style per-thread stacked bars.
    pub fn render(&self, title: &str) -> String {
        let labels: Vec<String> = (0..self.times.len())
            .map(|t| format!("thread {t:>2}"))
            .collect();
        let segments: Vec<String> =
            Phase::ALL.iter().map(|p| p.label().to_string()).collect();
        crate::util::tables::stacked_bars(title, &labels, &segments, &self.times, 60)
    }

    /// Machine-readable profile (the `profile.json` of `lqcd solve
    /// --profile`): thread count, per-phase totals + max/mean imbalance,
    /// per-thread phase seconds and flops. Deterministic key order.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"threads\": {},\n", self.nthreads()));
        s.push_str("  \"phases\": {\n");
        for (i, &p) in Phase::ALL.iter().enumerate() {
            s.push_str(&format!(
                "    \"{}\": {{\"seconds\": {:.9}, \"imbalance\": {:.6}}}{}\n",
                p.label(),
                self.phase_total(p),
                self.imbalance(p),
                if i + 1 < Phase::ALL.len() { "," } else { "" }
            ));
        }
        s.push_str("  },\n  \"per_thread\": [\n");
        for tid in 0..self.nthreads() {
            let times: Vec<String> = self.times[tid]
                .iter()
                .map(|t| format!("{t:.9}"))
                .collect();
            s.push_str(&format!(
                "    {{\"tid\": {}, \"seconds\": [{}], \"flops\": {}}}{}\n",
                tid,
                times.join(", "),
                self.flops[tid],
                if tid + 1 < self.nthreads() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_per_thread_and_phase() {
        let p = Profiler::new(3);
        p.add(0, Phase::Bulk, 1_000_000);
        p.add(0, Phase::Bulk, 500_000);
        p.add(2, Phase::Eo2, 2_000_000);
        assert!((p.seconds(0, Phase::Bulk) - 1.5e-3).abs() < 1e-12);
        assert_eq!(p.seconds(1, Phase::Bulk), 0.0);
        assert!((p.seconds(2, Phase::Eo2) - 2e-3).abs() < 1e-12);
    }

    #[test]
    fn scope_times_work() {
        let p = Profiler::new(1);
        let r = p.scope(0, Phase::Eo1, || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(r, 42);
        assert!(p.seconds(0, Phase::Eo1) >= 4e-3);
    }

    #[test]
    fn report_imbalance() {
        let p = Profiler::new(4);
        for tid in 0..4 {
            p.add(tid, Phase::Eo2, 1_000_000);
        }
        p.add(3, Phase::Eo2, 3_000_000); // thread 3 is 4x the others
        let r = p.snapshot();
        let imb = r.imbalance(Phase::Eo2);
        assert!(imb > 2.0, "imbalance {imb}");
        assert!((r.phase_total(Phase::Eo2) - 7e-3).abs() < 1e-9);
    }

    #[test]
    fn reset_clears() {
        let p = Profiler::new(2);
        p.add(1, Phase::Barrier, 7);
        p.add_flops(1, 99);
        p.reset();
        assert_eq!(p.seconds(1, Phase::Barrier), 0.0);
        assert_eq!(p.thread_flops(1), 0);
    }

    #[test]
    fn render_contains_threads_and_legend() {
        let p = Profiler::new(2);
        p.add(0, Phase::Bulk, 1000);
        p.add(1, Phase::Eo1, 500);
        let s = p.snapshot().render("fig");
        assert!(s.contains("thread  0"));
        assert!(s.contains("legend:"));
        assert!(s.contains("EO2"));
    }

    #[test]
    fn json_reports_thread_count_and_parses() {
        let p = Profiler::new(2);
        p.add(0, Phase::Bulk, 1_000_000);
        p.add(1, Phase::Blas, 500_000);
        p.add_flops(0, 1234);
        let text = p.snapshot().to_json();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(j.get("threads").unwrap().as_usize(), Some(2));
        let phases = j.get("phases").unwrap();
        let bulk_secs = phases
            .get("bulk")
            .unwrap()
            .get("seconds")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(bulk_secs > 0.0);
        let per = j.get("per_thread").unwrap().as_arr().unwrap();
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].get("flops").unwrap().as_usize(), Some(1234));
        assert_eq!(
            per[1].get("seconds").unwrap().as_arr().unwrap().len(),
            Phase::ALL.len()
        );
    }
}
