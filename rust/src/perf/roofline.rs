//! Byte/flop accounting and roofline/efficiency conversions between this
//! host and the paper's A64FX numbers.
//!
//! The `*_bytes` models below are the single source of truth for
//! "bytes one iteration streams through memory": the solver bench uses
//! them to report effective GB/s, and the `perf::tune` sweeps use the
//! same models so a fitted roofline and a bench measurement are
//! directly comparable (ISSUE 6 / ROADMAP item 5).

use crate::lattice::{EoLayout, Geometry, LatticeDims};

/// Bytes touched per site by one Wilson matrix application in single
/// precision: the paper quotes B/F = 1.12 at 1368 flop/site.
pub const WILSON_BF: f64 = 1.12;

/// Bytes of one even/odd spinor field at `elem_bytes` per real.
pub fn spinor_field_bytes(geom: &Geometry, elem_bytes: usize) -> u64 {
    (EoLayout::new(geom).spinor_len() * elem_bytes) as u64
}

/// Bytes of the full gauge stream (8 link blocks: 4 directions x 2
/// parities) at `reals_per_link` reals each (18 full, 12 two-row).
pub fn gauge_stream_bytes(geom: &Geometry, elem_bytes: usize, reals_per_link: usize) -> u64 {
    let layout = EoLayout::new(geom);
    (8 * layout.ntiles() * reals_per_link * layout.vlen() * elem_bytes) as u64
}

/// Bytes one M-hat (even-odd Wilson) application streams: two hopping
/// passes — each reading the source spinor and gauge blocks and writing
/// the destination — plus the fused `-kappa²` xpay tail's re-read of
/// the input field.
pub fn meo_apply_bytes(geom: &Geometry, elem_bytes: usize, reals_per_link: usize) -> u64 {
    let f = spinor_field_bytes(geom, elem_bytes);
    let g = gauge_stream_bytes(geom, elem_bytes, reals_per_link);
    2 * (2 * f + g) + f
}

/// Bytes one CGNR iteration streams through memory (model).
///
/// The normal operator apply is 4 hopping passes; each streams the
/// source field in, the destination field out, and the 8 gauge blocks
/// (4 directions x 2 parities). The fused pipeline adds the tail reads
/// (`b` of the xpay tail, twice) and the dot-capture re-read of `p`
/// inside the apply, then two BLAS passes (combined x/r update: 4 reads
/// + 2 writes; p xpay: 2 reads + 1 write). The unfused reference
/// (`UnfusedMdagM`, the pre-fusion pipeline) runs the same 4 hopping
/// passes plus two in-place gamma5 passes, two 3-stream xpay tails, and
/// the dot / axpy / axpy / norm² / xpay chain as separate passes.
pub fn cg_iter_bytes(geom: &Geometry, elem_bytes: usize, fused: bool) -> u64 {
    let f = spinor_field_bytes(geom, elem_bytes);
    let g = gauge_stream_bytes(geom, elem_bytes, 18);
    let hop4 = 4 * (2 * f + g);
    if fused {
        // apply(+tails +capture): hop4 + 2 tail reads + capture read of p
        // update: x,r,p,ap read + x,r write ; xpay: p,r read + p write
        hop4 + 3 * f + 6 * f + 3 * f
    } else {
        // apply: hop4 + 2 gamma5 (2f each) + 2 xpay tails (3f each)
        // dot(2f) + axpy(3f) + axpy(3f) + norm2(f) + xpay(3f)
        hop4 + 4 * f + 6 * f + 12 * f
    }
}

/// Bytes one *block* CGNR iteration streams for `nrhs` right-hand
/// sides (model): the 4 hopping passes stream the 8 gauge blocks ONCE
/// each — that is the amortization the block field buys — while every
/// spinor stream (kernel source/destination, fused tails, capture
/// re-read, and the two BLAS passes) is paid once per RHS. The gauge
/// term scales with `reals_per_link` (18 full, 12 two-row compressed).
/// At nrhs = 1 with full links this reduces exactly to
/// `cg_iter_bytes(geom, eb, true)`.
pub fn block_cg_iter_bytes(
    geom: &Geometry,
    elem_bytes: usize,
    nrhs: u64,
    reals_per_link: usize,
) -> u64 {
    let f = spinor_field_bytes(geom, elem_bytes);
    let g = gauge_stream_bytes(geom, elem_bytes, reals_per_link);
    // gauge once, spinor in/out per RHS, per hopping pass
    let hop4 = 4 * (2 * f * nrhs + g);
    hop4 + (3 + 6 + 3) * f * nrhs
}

/// Modeled bytes per site per RHS of one iteration (the gauge-stream
/// amortization metric: strictly decreasing in nrhs at fixed lattice).
pub fn bytes_per_site(geom: &Geometry, bytes_per_iter: u64, nrhs: u64) -> f64 {
    let sites = EoLayout::new(geom).nsites() as u64 * nrhs;
    bytes_per_iter as f64 / sites as f64
}

/// Data footprint (bytes) of the gauge + spinor working set of one local
/// lattice in single precision (paper §4.1: 18 MiB + 6 MiB at 16^4).
pub fn working_set_bytes(dims: LatticeDims) -> usize {
    let sites = dims.volume();
    let gauge = sites * 4 * 9 * 2 * 4; // 4 dirs x 3x3 complex f32
    let spinor = sites * 4 * 3 * 2 * 4; // 4 spin x 3 color complex f32
    gauge + spinor
}

/// Efficiency of a measurement relative to a peak (fraction).
pub fn efficiency(measured_gflops: f64, peak_gflops: f64) -> f64 {
    measured_gflops / peak_gflops
}

/// Translate "fraction of memory roofline achieved on this host" into the
/// GFlops the same fraction would give on a Fugaku node — the
/// shape-preserving normalization used in EXPERIMENTS.md.
pub fn project_to_a64fx(
    measured_gflops: f64,
    host_roofline_gflops: f64,
    a64fx_roofline_gflops: f64,
) -> f64 {
    measured_gflops / host_roofline_gflops * a64fx_roofline_gflops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_footprint_16_4() {
        // paper §4.1: at 16^4, gauge 18 MiB and spinor 6 MiB
        let dims = LatticeDims::new(16, 16, 16, 16).unwrap();
        let sites = dims.volume();
        let gauge = sites * 4 * 9 * 2 * 4;
        let spinor = sites * 4 * 3 * 2 * 4;
        assert_eq!(gauge, 18 * 1024 * 1024);
        assert_eq!(spinor, 6 * 1024 * 1024);
        assert_eq!(working_set_bytes(dims), 24 * 1024 * 1024);
    }

    #[test]
    fn projection_is_linear() {
        let projected = project_to_a64fx(5.0, 10.0, 914.0);
        assert!((projected - 457.0).abs() < 1e-9);
        assert!((efficiency(457.0, 914.0) - 0.5).abs() < 1e-12);
    }
}
