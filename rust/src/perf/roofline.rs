//! Byte/flop accounting and roofline/efficiency conversions between this
//! host and the paper's A64FX numbers.

use crate::lattice::LatticeDims;

/// Bytes touched per site by one Wilson matrix application in single
/// precision: the paper quotes B/F = 1.12 at 1368 flop/site.
pub const WILSON_BF: f64 = 1.12;

/// Data footprint (bytes) of the gauge + spinor working set of one local
/// lattice in single precision (paper §4.1: 18 MiB + 6 MiB at 16^4).
pub fn working_set_bytes(dims: LatticeDims) -> usize {
    let sites = dims.volume();
    let gauge = sites * 4 * 9 * 2 * 4; // 4 dirs x 3x3 complex f32
    let spinor = sites * 4 * 3 * 2 * 4; // 4 spin x 3 color complex f32
    gauge + spinor
}

/// Efficiency of a measurement relative to a peak (fraction).
pub fn efficiency(measured_gflops: f64, peak_gflops: f64) -> f64 {
    measured_gflops / peak_gflops
}

/// Translate "fraction of memory roofline achieved on this host" into the
/// GFlops the same fraction would give on a Fugaku node — the
/// shape-preserving normalization used in EXPERIMENTS.md.
pub fn project_to_a64fx(
    measured_gflops: f64,
    host_roofline_gflops: f64,
    a64fx_roofline_gflops: f64,
) -> f64 {
    measured_gflops / host_roofline_gflops * a64fx_roofline_gflops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_footprint_16_4() {
        // paper §4.1: at 16^4, gauge 18 MiB and spinor 6 MiB
        let dims = LatticeDims::new(16, 16, 16, 16).unwrap();
        let sites = dims.volume();
        let gauge = sites * 4 * 9 * 2 * 4;
        let spinor = sites * 4 * 3 * 2 * 4;
        assert_eq!(gauge, 18 * 1024 * 1024);
        assert_eq!(spinor, 6 * 1024 * 1024);
        assert_eq!(working_set_bytes(dims), 24 * 1024 * 1024);
    }

    #[test]
    fn projection_is_linear() {
        let projected = project_to_a64fx(5.0, 10.0, 914.0);
        assert!((projected - 457.0).abs() < 1e-9);
        assert!((efficiency(457.0, 914.0) - 0.5).abs() < 1e-12);
    }
}
