//! Machine models: the A64FX/Fugaku node the paper ran on, and a
//! calibration of *this* host so measured GFlops can be normalized into
//! paper-scale estimates (DESIGN.md section 4: substitution rule).

use std::time::Instant;

/// A64FX (Fugaku node) parameters, paper §3.1.
#[derive(Clone, Copy, Debug)]
pub struct A64fx {
    /// single-precision peak per node (normal mode 2.0 GHz), GFlops
    pub peak_sp_gflops: f64,
    /// double-precision peak per node, GFlops
    pub peak_dp_gflops: f64,
    /// HBM bandwidth per node, GB/s
    pub mem_bw_gbs: f64,
    /// L2 size per CMG, bytes
    pub l2_per_cmg: usize,
    pub cmgs: usize,
    pub cores_per_cmg: usize,
}

impl A64fx {
    pub const fn fugaku_normal() -> A64fx {
        A64fx {
            peak_sp_gflops: 6144.0,
            peak_dp_gflops: 3072.0,
            mem_bw_gbs: 1024.0,
            l2_per_cmg: 8 * 1024 * 1024,
            cmgs: 4,
            cores_per_cmg: 12,
        }
    }

    /// Memory-roofline bound for a kernel with byte/flop ratio `bf`
    /// (B/F = 1.12 for the Wilson matrix, paper §2), in GFlops.
    pub fn mem_roofline_gflops(&self, bf: f64) -> f64 {
        self.mem_bw_gbs / bf
    }

    /// Does a working set fit in the node's total L2?
    pub fn fits_l2(&self, bytes: usize) -> bool {
        bytes <= self.l2_per_cmg * self.cmgs
    }
}

/// Measured characteristics of the host running the benchmarks.
#[derive(Clone, Copy, Debug)]
pub struct HostCalibration {
    /// single-core f32 FMA throughput estimate, GFlops
    pub core_sp_gflops: f64,
    /// single-thread STREAM-triad bandwidth (read+read+write), GB/s
    pub mem_bw_gbs: f64,
    /// saturated multi-threaded STREAM-triad bandwidth, GB/s — the
    /// whole-host memory roofline (a single thread rarely drives the
    /// full bus; the old read-only single-thread sweep underestimated
    /// multi-core hosts badly)
    pub mem_bw_saturated_gbs: f64,
    /// smallest thread count that reached the saturated bandwidth
    /// (within [`SATURATION_FRACTION`]) — the measured knee
    pub saturation_threads: usize,
}

/// A thread count "saturates" the memory bus once it reaches this
/// fraction of the best bandwidth any count achieved.
pub const SATURATION_FRACTION: f64 = 0.95;

/// One STREAM-triad pass `a[i] = b[i] + s * c[i]`: two read streams and
/// one write stream per element, the canonical bandwidth kernel.
fn triad_pass(a: &mut [f32], b: &[f32], c: &[f32], s: f32) {
    for ((x, &y), &z) in a.iter_mut().zip(b.iter()).zip(c.iter()) {
        *x = y + s * z;
    }
}

/// STREAM-triad bandwidth at a fixed thread count, GB/s. Each thread
/// owns a private a/b/c triple (first-touch local), all threads start
/// together behind a barrier, and the wall time covers `reps` passes.
pub fn triad_bw_gbs(nthreads: usize, elems_per_thread: usize, reps: usize) -> f64 {
    let nthreads = nthreads.max(1);
    let start = std::sync::Barrier::new(nthreads);
    let mut dt = 0.0f64;
    std::thread::scope(|scope| {
        let start = &start;
        let mut handles = Vec::with_capacity(nthreads - 1);
        for _ in 1..nthreads {
            handles.push(scope.spawn(move || {
                let mut a = vec![0.0f32; elems_per_thread];
                let b = vec![1.0f32; elems_per_thread];
                let c = vec![2.0f32; elems_per_thread];
                start.wait();
                for _ in 0..reps {
                    triad_pass(&mut a, &b, &c, 3.0);
                }
                std::hint::black_box(a[0]);
            }));
        }
        // the caller participates as thread 0 and owns the clock
        let mut a = vec![0.0f32; elems_per_thread];
        let b = vec![1.0f32; elems_per_thread];
        let c = vec![2.0f32; elems_per_thread];
        start.wait();
        let t0 = Instant::now();
        for _ in 0..reps {
            triad_pass(&mut a, &b, &c, 3.0);
        }
        std::hint::black_box(a[0]);
        for h in handles {
            h.join().unwrap();
        }
        dt = t0.elapsed().as_secs_f64();
    });
    let bytes = 3 * 4 * elems_per_thread * nthreads * reps;
    bytes as f64 / dt.max(1e-9) / 1e9
}

/// The thread counts the triad sweep samples on a host with `cores`
/// cores: 1, doubling up to the core count, always ending at `cores`.
pub fn triad_thread_sweep(cores: usize) -> Vec<usize> {
    let cores = cores.max(1);
    let mut counts = vec![1usize];
    let mut t = 2;
    while t < cores {
        counts.push(t);
        t *= 2;
    }
    if cores > 1 {
        counts.push(cores);
    }
    counts
}

/// Quick (~hundreds of ms) calibration of this host.
pub fn calibrate_host() -> HostCalibration {
    // --- FMA throughput: 8 independent f32x8 accumulator chains ---------
    const LANES: usize = 8;
    const CHAINS: usize = 8;
    let mut acc = [[1.0f32; LANES]; CHAINS];
    let a = [1.000_1f32; LANES];
    let b = [0.999_9f32; LANES];
    let iters = 2_000_000usize;
    let t0 = Instant::now();
    for _ in 0..iters {
        for c in 0..CHAINS {
            for l in 0..LANES {
                acc[c][l] = acc[c][l] * a[l] + b[l];
            }
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    // keep the result alive
    let sink: f32 = acc.iter().flatten().sum();
    std::hint::black_box(sink);
    let flops = (iters * CHAINS * LANES * 2) as f64;
    let core_sp_gflops = flops / dt / 1e9;

    // --- streaming bandwidth: multi-threaded STREAM triad ---------------
    // Total working set ~96 MiB (far past any LLC) split across the
    // threads; swept over 1, 2, 4, ... cores to find both the 1-thread
    // number and the saturated whole-host bandwidth.
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let total_elems = 32 * 1024 * 1024 / 4; // 32 MiB per array, 3 arrays
    let mut mem_bw_gbs = 0.0;
    let mut samples: Vec<(usize, f64)> = Vec::new();
    for &t in &triad_thread_sweep(cores) {
        let gbs = triad_bw_gbs(t, total_elems / t, 2);
        if t == 1 {
            mem_bw_gbs = gbs;
        }
        samples.push((t, gbs));
    }
    let best = samples.iter().map(|&(_, g)| g).fold(0.0, f64::max);
    let saturation_threads = samples
        .iter()
        .find(|&&(_, g)| g >= SATURATION_FRACTION * best)
        .map(|&(t, _)| t)
        .unwrap_or(1);

    HostCalibration {
        core_sp_gflops,
        mem_bw_gbs,
        mem_bw_saturated_gbs: best,
        saturation_threads,
    }
}

impl HostCalibration {
    /// Memory-roofline bound on this host for byte/flop ratio `bf`,
    /// from the saturated (whole-host) bandwidth.
    pub fn mem_roofline_gflops(&self, bf: f64) -> f64 {
        self.mem_bw_saturated_gbs / bf
    }
}

/// Default worker-team size for the fused solver pipeline when
/// `solver.threads` is left unset: derived from this host's core count
/// through the bandwidth argument below. (Cheap — no calibration run.)
pub fn auto_solver_threads() -> usize {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    auto_solver_threads_for(cores)
}

/// Core-count → team-size heuristic behind [`auto_solver_threads`].
///
/// The Wilson solve is memory-bandwidth bound: the kernel needs ~1.12
/// bytes per flop while balanced nodes provide far less (A64FX: 1024
/// GB/s against 6144 GFlops ≈ 0.17 B/F, paper §2), so the memory bus
/// saturates at a small fraction of the cores and extra threads only
/// add barrier traffic. Half the cores is already past saturation on
/// every host this runs on; the cap is the paper's 12 threads per CMG
/// (one NUMA domain — beyond it the team would straddle memory
/// domains the single-rank pipeline doesn't partition for).
pub fn auto_solver_threads_for(cores: usize) -> usize {
    (cores / 2).clamp(1, 12)
}

/// Which bound produced the auto-derived team size (logged by the
/// launcher so `solver.threads` auto-selection is explainable).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AutoThreadBound {
    /// the bandwidth-saturation heuristic from the whole-machine core count
    Heuristic,
    /// clamped by `parallel.threads_per_rank`: a distributed config puts
    /// several ranks on this node, so the team must not size itself from
    /// the whole machine
    RankCap,
    /// taken from the per-machine tune cache: the bandwidth-saturation
    /// knee `lqcd tune` measured on this host, not the cores/2 guess
    Tuned,
}

impl std::fmt::Display for AutoThreadBound {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            AutoThreadBound::Heuristic => {
                "bandwidth-saturation heuristic from the core count"
            }
            AutoThreadBound::RankCap => {
                "clamped by parallel.threads_per_rank (multiple ranks share this machine)"
            }
            AutoThreadBound::Tuned => {
                "measured bandwidth-saturation knee from the tune cache"
            }
        })
    }
}

/// [`auto_solver_threads`] with an optional per-rank clamp: a
/// distributed run places `grid.size()` ranks on this one simulated
/// node, so sizing each rank's team from the whole machine's
/// `available_parallelism` oversubscribes it `nranks`-fold. Pass
/// `Some(parallel.threads_per_rank)` for multi-rank configs; returns
/// the team size and which bound won.
pub fn auto_solver_threads_capped(threads_per_rank: Option<usize>) -> (usize, AutoThreadBound) {
    auto_solver_threads_capped_for(
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        threads_per_rank,
    )
}

/// Pure core-count form of [`auto_solver_threads_capped`] (testable).
pub fn auto_solver_threads_capped_for(
    cores: usize,
    threads_per_rank: Option<usize>,
) -> (usize, AutoThreadBound) {
    let auto = auto_solver_threads_for(cores);
    match threads_per_rank {
        Some(cap) if cap.max(1) < auto => (cap.max(1), AutoThreadBound::RankCap),
        _ => (auto, AutoThreadBound::Heuristic),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a64fx_constants() {
        let m = A64fx::fugaku_normal();
        assert_eq!(m.cmgs * m.cores_per_cmg, 48);
        // B/F = 1.12 roofline ~ 914 GFlops; Table 1 best (448) is ~half
        let roof = m.mem_roofline_gflops(1.12);
        assert!((roof - 914.3).abs() < 1.0);
        assert!(448.0 / roof > 0.4 && 448.0 / roof < 0.6);
        assert!(m.fits_l2(24 * 1024 * 1024));
        assert!(!m.fits_l2(64 * 1024 * 1024));
    }

    #[test]
    fn auto_threads_heuristic() {
        assert_eq!(auto_solver_threads_for(1), 1);
        assert_eq!(auto_solver_threads_for(2), 1);
        assert_eq!(auto_solver_threads_for(4), 2);
        assert_eq!(auto_solver_threads_for(48), 12, "capped at one CMG");
        assert_eq!(auto_solver_threads_for(128), 12);
        let t = auto_solver_threads();
        assert!(t >= 1 && t <= 12);
    }

    #[test]
    fn auto_threads_rank_cap() {
        // single-rank: heuristic wins, no clamp applied
        assert_eq!(
            auto_solver_threads_capped_for(48, None),
            (12, AutoThreadBound::Heuristic)
        );
        // 4 ranks on a 48-core node, 4 threads each: the rank cap wins
        assert_eq!(
            auto_solver_threads_capped_for(48, Some(4)),
            (4, AutoThreadBound::RankCap)
        );
        // a generous per-rank budget does not inflate the heuristic
        assert_eq!(
            auto_solver_threads_capped_for(8, Some(12)),
            (4, AutoThreadBound::Heuristic)
        );
        // tie goes to the heuristic (nothing was clamped)
        assert_eq!(
            auto_solver_threads_capped_for(24, Some(12)),
            (12, AutoThreadBound::Heuristic)
        );
        // a zero cap still yields a runnable team
        assert_eq!(
            auto_solver_threads_capped_for(48, Some(0)),
            (1, AutoThreadBound::RankCap)
        );
    }

    #[test]
    fn host_calibration_sane() {
        let h = calibrate_host();
        // generous bounds: debug builds are ~50x slower than release
        assert!(
            h.core_sp_gflops > 0.01 && h.core_sp_gflops < 10_000.0,
            "{h:?}"
        );
        assert!(h.mem_bw_gbs > 0.05 && h.mem_bw_gbs < 10_000.0, "{h:?}");
        // saturated bandwidth is never below a modest fraction of the
        // 1-thread number (same kernel, more streams; allow scheduler
        // jitter on loaded machines)
        assert!(h.mem_bw_saturated_gbs > 0.5 * h.mem_bw_gbs, "{h:?}");
        assert!(h.saturation_threads >= 1, "{h:?}");
    }

    #[test]
    fn triad_thread_sweep_shape() {
        assert_eq!(triad_thread_sweep(1), vec![1]);
        assert_eq!(triad_thread_sweep(2), vec![1, 2]);
        assert_eq!(triad_thread_sweep(6), vec![1, 2, 4, 6]);
        assert_eq!(triad_thread_sweep(8), vec![1, 2, 4, 8]);
        assert_eq!(triad_thread_sweep(48), vec![1, 2, 4, 8, 16, 32, 48]);
    }

    #[test]
    fn triad_measures_positive_bandwidth() {
        let gbs = triad_bw_gbs(2, 64 * 1024, 2);
        assert!(gbs > 0.0 && gbs.is_finite(), "{gbs}");
    }
}
