//! Full-stack telemetry: span tracing, metrics, and slowdown detection.
//!
//! The paper's central empirical instrument is the FAPP profiler readout
//! (Figs. 8/9): per-thread, per-phase time bars that "may signal an
//! unexpected source of slow-down". The aggregate bars live in
//! [`crate::coordinator::Profiler`]; this module adds the *when* and
//! *where*: structured spans `(phase, rank, thread, iter, t_start,
//! t_end, bytes, flops)` collected into lock-free per-thread ring
//! buffers, a metrics registry with deterministic fixed-bucket
//! histograms (p50/p95/p99), Chrome-trace / Perfetto and metrics.json
//! exporters, and an automated slowdown detector that flags iterations
//! whose comm-wait/barrier time is an outlier against a trailing-window
//! median + k·MAD baseline.
//!
//! Overhead contract: recording is one branch when tracing is disabled
//! (the tracer is simply absent) and a bounds check + ring push when
//! enabled. Rings never reallocate: overflow increments a drop counter
//! so memory stays bounded and the hot path stays allocation-free.
//! Telemetry never feeds back into solver arithmetic — residual
//! histories are bitwise identical with tracing on, off, or absent
//! (pinned by `rust/tests/telemetry.rs`).

use std::cell::UnsafeCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::time::Instant;

use crate::util::json::JsonWriter;

/// Span codes 0..=7 mirror [`crate::coordinator::Phase`] (EO1, bulk,
/// comm-wait, EO2, barrier, blas, restart, checkpoint). Codes >= 16 are
/// transport events recorded by `comm::world` outside any profiler
/// phase.
pub const EV_SEND: u8 = 16;
pub const EV_RETRANSMIT: u8 = 17;
pub const EV_TIMEOUT: u8 = 18;
pub const EV_DELAY: u8 = 19;
pub const EV_CORRUPT: u8 = 20;
pub const EV_DUPLICATE: u8 = 21;
pub const EV_ZEROFILL: u8 = 22;

/// Human-readable name of a span code; phase labels match
/// `Phase::label` so the Perfetto tracks line up with the Fig. 8/9 bars.
pub fn span_label(code: u8) -> &'static str {
    match code {
        0 => "EO1(pack)",
        1 => "bulk",
        2 => "comm-wait",
        3 => "EO2(unpack)",
        4 => "barrier",
        5 => "blas",
        6 => "restart",
        7 => "checkpoint",
        EV_SEND => "send",
        EV_RETRANSMIT => "retransmit",
        EV_TIMEOUT => "timeout",
        EV_DELAY => "delay-inject",
        EV_CORRUPT => "corrupt-detected",
        EV_DUPLICATE => "duplicate-dropped",
        EV_ZEROFILL => "zero-fill",
        _ => "event",
    }
}

/// One traced span (or instantaneous event: `t_start_ns == t_end_ns`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    pub code: u8,
    pub rank: u32,
    pub thread: u32,
    /// solver iteration the span belongs to (the tag current at record
    /// time; see [`Tracer::set_iter`])
    pub iter: u32,
    /// nanoseconds since the tracer's epoch
    pub t_start_ns: u64,
    pub t_end_ns: u64,
    pub bytes: u64,
    pub flops: u64,
}

impl SpanRecord {
    pub fn seconds(&self) -> f64 {
        (self.t_end_ns - self.t_start_ns) as f64 * 1e-9
    }
}

/// One bounded single-writer span ring. Thread `tid` of the team is the
/// only writer of ring `tid` (comm events ride ring 0: the transport is
/// FUNNELED and the rank master *is* team tid 0), so an `UnsafeCell`
/// plus the team's region-completion synchronization is enough — no
/// locks on the record path.
struct Ring {
    buf: UnsafeCell<Vec<SpanRecord>>,
    dropped: AtomicU64,
}

// SAFETY: the UnsafeCell buffer is written by exactly one thread (ring i
// belongs to team tid i; comm events ride ring 0 under the FUNNELED
// transport) and `drain` runs only after every recorder quiesced, so no
// two threads ever access a buffer concurrently.
unsafe impl Sync for Ring {}

/// Lock-free span collector: one bounded ring per thread plus the
/// current-iteration tag. Shared as `Arc` between the profiler (which
/// records phase scopes), the transport (which records events) and the
/// exporter (which drains after the solve).
pub struct Tracer {
    epoch: Instant,
    rank: u32,
    cap: usize,
    iter: AtomicU32,
    rings: Vec<Ring>,
}

impl Tracer {
    /// `cap` spans per thread ring; overflow is counted, not stored.
    pub fn new(nthreads: usize, cap: usize, rank: usize) -> Tracer {
        Tracer {
            epoch: Instant::now(),
            rank: rank as u32,
            cap,
            iter: AtomicU32::new(0),
            rings: (0..nthreads.max(1))
                .map(|_| Ring {
                    buf: UnsafeCell::new(Vec::with_capacity(cap)),
                    dropped: AtomicU64::new(0),
                })
                .collect(),
        }
    }

    /// Tag subsequent spans with the solver iteration they belong to.
    pub fn set_iter(&self, iter: usize) {
        self.iter.store(iter as u32, Ordering::Relaxed);
    }

    /// Nanoseconds since the tracer's epoch.
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Record a span on thread `tid`'s ring.
    ///
    /// Concurrency contract: at most one OS thread records on a given
    /// `tid` at a time (the team assigns tids uniquely within a region;
    /// regions are serialized; the FUNNELED transport records from the
    /// rank master, which is team tid 0's thread).
    pub fn record(
        &self,
        tid: usize,
        code: u8,
        t_start_ns: u64,
        t_end_ns: u64,
        bytes: u64,
        flops: u64,
    ) {
        let ring = &self.rings[tid.min(self.rings.len() - 1)];
        // SAFETY: ring `tid` is single-writer (this thread); see Ring.
        let buf = unsafe { &mut *ring.buf.get() };
        if buf.len() < self.cap {
            buf.push(SpanRecord {
                code,
                rank: self.rank,
                thread: tid as u32,
                iter: self.iter.load(Ordering::Relaxed),
                t_start_ns,
                t_end_ns,
                bytes,
                flops,
            });
        } else {
            ring.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record an instantaneous event (retransmit, timeout, ...).
    pub fn event(&self, tid: usize, code: u8, bytes: u64) {
        let t = self.now_ns();
        self.record(tid, code, t, t, bytes, 0);
    }

    /// Collect every ring into one sorted span list plus the total drop
    /// count. Call only after all recording threads have quiesced (the
    /// solve returned / the world joined).
    pub fn drain(&self) -> TraceData {
        let mut spans = Vec::new();
        let mut dropped = 0u64;
        for ring in &self.rings {
            // SAFETY: drain's contract: all recorders have quiesced,
            // so the shared read cannot race a writer.
            spans.extend_from_slice(unsafe { &*ring.buf.get() });
            dropped += ring.dropped.load(Ordering::Relaxed);
        }
        let mut data = TraceData { spans, dropped };
        data.sort();
        data
    }
}

/// Drained spans of one rank (or, after [`TraceData::merge`], a world).
#[derive(Clone, Debug, Default)]
pub struct TraceData {
    pub spans: Vec<SpanRecord>,
    pub dropped: u64,
}

impl TraceData {
    fn sort(&mut self) {
        self.spans.sort_by_key(|s| {
            (s.rank, s.thread, s.t_start_ns, s.t_end_ns, s.code)
        });
    }

    /// Merge per-rank traces into one world trace (sorted, drop counts
    /// summed). Each rank keeps its own epoch; spans stay comparable
    /// within a rank×thread track, which is what the timeline shows.
    pub fn merge(parts: Vec<TraceData>) -> TraceData {
        let mut out = TraceData::default();
        for p in parts {
            out.spans.extend(p.spans);
            out.dropped += p.dropped;
        }
        out.sort();
        out
    }

    /// Chrome-trace / Perfetto JSON: complete events ("ph":"X"), one
    /// track per rank (pid) × thread (tid), timestamps in microseconds.
    /// Open with https://ui.perfetto.dev or chrome://tracing.
    pub fn chrome_trace_json(&self) -> String {
        let mut w = JsonWriter::new();
        w.obj_begin();
        w.key("traceEvents");
        w.arr_begin();
        for s in &self.spans {
            w.obj_begin();
            w.key("name");
            w.str_val(span_label(s.code));
            w.key("ph");
            w.str_val("X");
            w.key("ts");
            w.raw(&format!("{:.3}", s.t_start_ns as f64 / 1e3));
            w.key("dur");
            w.raw(&format!("{:.3}", (s.t_end_ns - s.t_start_ns) as f64 / 1e3));
            w.key("pid");
            w.uint(s.rank as u64);
            w.key("tid");
            w.uint(s.thread as u64);
            w.key("args");
            w.obj_begin();
            w.key("iter");
            w.uint(s.iter as u64);
            w.key("bytes");
            w.uint(s.bytes);
            w.key("flops");
            w.uint(s.flops);
            w.obj_end();
            w.obj_end();
        }
        w.arr_end();
        w.key("displayTimeUnit");
        w.str_val("ms");
        w.key("droppedSpans");
        w.uint(self.dropped);
        w.obj_end();
        w.finish()
    }
}

/// Number of log-spaced histogram buckets.
pub const HIST_BUCKETS: usize = 64;
/// Bucket range: `HIST_LO * 10^(i * HIST_DECADES / HIST_BUCKETS)` for
/// bucket edge `i` — 1 ns .. 1000 s covers every phase time we see.
const HIST_LO: f64 = 1e-9;
const HIST_DECADES: f64 = 12.0;

/// Deterministic fixed-bucket histogram (log-spaced over 1e-9..1e3).
/// Quantiles return the geometric midpoint of the covering bucket,
/// clamped to the observed `[min, max]` — so an empty histogram reads
/// 0.0 and one-sample / all-equal histograms read the exact value.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: [u64; HIST_BUCKETS],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: [0; HIST_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket(v: f64) -> usize {
        if v <= HIST_LO {
            return 0;
        }
        let idx = ((v / HIST_LO).log10() / HIST_DECADES * HIST_BUCKETS as f64) as usize;
        idx.min(HIST_BUCKETS - 1)
    }

    pub fn observe(&mut self, v: f64) {
        if !v.is_finite() || v < 0.0 {
            return;
        }
        self.counts[Self::bucket(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// `q` in [0, 1]; see the type docs for the edge-case contract.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q * self.count as f64).ceil()).max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if c > 0 && cum >= target {
                let lo = HIST_LO
                    * 10f64.powf(i as f64 * HIST_DECADES / HIST_BUCKETS as f64);
                let hi = HIST_LO
                    * 10f64.powf((i + 1) as f64 * HIST_DECADES / HIST_BUCKETS as f64);
                return (lo * hi).sqrt().clamp(self.min, self.max);
            }
        }
        self.max
    }
}

/// Metrics registry: named counters, gauges, and histograms with
/// deterministic (BTreeMap) iteration order for the JSON export.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn counter(&mut self, name: &str, delta: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += delta;
    }

    pub fn gauge(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    pub fn observe(&mut self, name: &str, value: f64) {
        self.histograms
            .entry(name.to_string())
            .or_default()
            .observe(value);
    }

    pub fn get_counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// metrics.json: counters, gauges, histogram summaries
    /// (count/sum/min/max/p50/p95/p99) and the slowdown report.
    pub fn to_json(&self, slowdowns: &[Slowdown]) -> String {
        let mut w = JsonWriter::new();
        w.obj_begin();
        w.key("counters");
        w.obj_begin();
        for (k, v) in &self.counters {
            w.key(k);
            w.uint(*v);
        }
        w.obj_end();
        w.key("gauges");
        w.obj_begin();
        for (k, v) in &self.gauges {
            w.key(k);
            w.num(*v);
        }
        w.obj_end();
        w.key("histograms");
        w.obj_begin();
        for (k, h) in &self.histograms {
            w.key(k);
            w.obj_begin();
            w.key("count");
            w.uint(h.count());
            w.key("sum");
            w.num(h.sum());
            w.key("min");
            w.num(h.min());
            w.key("max");
            w.num(h.max());
            w.key("p50");
            w.num(h.quantile(0.50));
            w.key("p95");
            w.num(h.quantile(0.95));
            w.key("p99");
            w.num(h.quantile(0.99));
            w.obj_end();
        }
        w.obj_end();
        w.key("slowdowns");
        w.raw(&slowdown_summary(slowdowns));
        w.obj_end();
        w.finish()
    }
}

/// Slowdown-detector knobs (config `[telemetry]`). An iteration is
/// flagged when its phase time exceeds *all* of: the absolute floor,
/// `factor ×` the trailing-window median, and `median + k × MAD`. The
/// conjunction keeps clean-but-jittery CI runs silent while a 40 ms+
/// injected delay on a microsecond-scale phase is unmissable.
#[derive(Clone, Copy, Debug)]
pub struct SlowdownConfig {
    /// trailing samples forming the baseline (no flags before the
    /// window fills)
    pub window: usize,
    /// MAD multiplier
    pub k: f64,
    /// multiplicative guard vs the window median
    pub factor: f64,
    /// absolute floor in seconds: never flag below this
    pub min_secs: f64,
}

impl Default for SlowdownConfig {
    fn default() -> Self {
        SlowdownConfig {
            window: 8,
            k: 6.0,
            factor: 3.0,
            min_secs: 2e-3,
        }
    }
}

/// One flagged iteration.
#[derive(Clone, Debug)]
pub struct Slowdown {
    pub rank: u32,
    /// span code of the phase (see [`span_label`])
    pub code: u8,
    pub iter: u32,
    pub seconds: f64,
    /// trailing-window median the sample was judged against
    pub median: f64,
    pub mad: f64,
}

fn median_sorted(s: &[f64]) -> f64 {
    let n = s.len();
    if n == 0 {
        return 0.0;
    }
    if n % 2 == 1 {
        s[n / 2]
    } else {
        0.5 * (s[n / 2 - 1] + s[n / 2])
    }
}

/// Flag outliers in one time series. Returns `(index, median, mad)` per
/// flagged sample; the first `cfg.window` samples are baseline only.
pub fn detect_outliers(series: &[f64], cfg: &SlowdownConfig) -> Vec<(usize, f64, f64)> {
    let mut out = Vec::new();
    if series.len() <= cfg.window || cfg.window == 0 {
        return out;
    }
    for i in cfg.window..series.len() {
        let window = &series[i - cfg.window..i];
        let mut sorted = window.to_vec();
        sorted.sort_by(f64::total_cmp);
        let med = median_sorted(&sorted);
        let mut dev: Vec<f64> = window.iter().map(|x| (x - med).abs()).collect();
        dev.sort_by(f64::total_cmp);
        let mad = median_sorted(&dev);
        let x = series[i];
        if x > cfg.min_secs && x > med * cfg.factor && x > med + cfg.k * mad {
            out.push((i, med, mad));
        }
    }
    out
}

/// Per-iteration critical-path time of one (rank, phase): span durations
/// summed per thread within an iteration, then the max across threads.
pub fn phase_series(spans: &[SpanRecord], rank: u32, code: u8) -> Vec<(u32, f64)> {
    let mut per: BTreeMap<u32, BTreeMap<u32, u64>> = BTreeMap::new();
    for s in spans {
        if s.rank == rank && s.code == code {
            *per.entry(s.iter).or_default().entry(s.thread).or_insert(0) +=
                s.t_end_ns - s.t_start_ns;
        }
    }
    per.into_iter()
        .map(|(iter, threads)| {
            let max = threads.values().copied().max().unwrap_or(0);
            (iter, max as f64 * 1e-9)
        })
        .collect()
}

/// Run the detector over the wait-dominated phases (comm-wait, barrier —
/// the paper's Fig. 8/9 "unexpected slow-down" signals) of every rank.
pub fn detect_slowdowns(spans: &[SpanRecord], cfg: &SlowdownConfig) -> Vec<Slowdown> {
    let mut ranks: Vec<u32> = spans.iter().map(|s| s.rank).collect();
    ranks.sort_unstable();
    ranks.dedup();
    let mut out = Vec::new();
    for &rank in &ranks {
        // codes 2/4 = comm-wait / barrier (Phase mirror, see span_label)
        for code in [2u8, 4] {
            let series = phase_series(spans, rank, code);
            let values: Vec<f64> = series.iter().map(|p| p.1).collect();
            for (i, median, mad) in detect_outliers(&values, cfg) {
                out.push(Slowdown {
                    rank,
                    code,
                    iter: series[i].0,
                    seconds: values[i],
                    median,
                    mad,
                });
            }
        }
    }
    out.sort_by_key(|s| (s.rank, s.code, s.iter));
    out
}

/// The `slowdowns:` summary object — printed as a CLI line and embedded
/// verbatim in metrics.json, so CI can grep either.
pub fn slowdown_summary(slowdowns: &[Slowdown]) -> String {
    let mut w = JsonWriter::new();
    w.obj_begin();
    w.key("count");
    w.uint(slowdowns.len() as u64);
    w.key("flagged");
    w.arr_begin();
    for s in slowdowns {
        w.obj_begin();
        w.key("rank");
        w.uint(s.rank as u64);
        w.key("phase");
        w.str_val(span_label(s.code));
        w.key("iter");
        w.uint(s.iter as u64);
        w.key("seconds");
        w.num(s.seconds);
        w.key("median");
        w.num(s.median);
        w.key("mad");
        w.num(s.mad);
        w.obj_end();
    }
    w.arr_end();
    w.obj_end();
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracer_records_and_tags_iterations() {
        let t = Tracer::new(2, 16, 3);
        t.set_iter(7);
        t.record(1, 1, 100, 200, 64, 99);
        t.event(0, EV_RETRANSMIT, 32);
        let data = t.drain();
        assert_eq!(data.spans.len(), 2);
        assert_eq!(data.dropped, 0);
        // sorted by (rank, thread, ...): tid 0 event first
        assert_eq!(data.spans[0].code, EV_RETRANSMIT);
        assert_eq!(data.spans[0].rank, 3);
        assert_eq!(data.spans[0].iter, 7);
        let s = data.spans[1];
        assert_eq!((s.thread, s.code, s.bytes, s.flops), (1, 1, 64, 99));
        assert_eq!(s.t_end_ns - s.t_start_ns, 100);
    }

    #[test]
    fn ring_overflow_is_counted_not_stored() {
        let t = Tracer::new(1, 4, 0);
        for i in 0..10u64 {
            t.record(0, 5, i, i + 1, 0, 0);
        }
        let data = t.drain();
        assert_eq!(data.spans.len(), 4, "ring capacity bounds memory");
        assert_eq!(data.dropped, 6, "overflow is drop-counted");
        // the ring keeps the oldest spans (no overwrite)
        assert_eq!(data.spans[0].t_start_ns, 0);
        assert_eq!(data.spans[3].t_start_ns, 3);
    }

    #[test]
    fn histogram_quantile_edge_cases() {
        let empty = Histogram::new();
        assert_eq!(empty.quantile(0.5), 0.0);
        assert_eq!(empty.count(), 0);
        assert_eq!(empty.min(), 0.0);
        assert_eq!(empty.max(), 0.0);

        let mut one = Histogram::new();
        one.observe(3.5e-4);
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(one.quantile(q), 3.5e-4, "one sample is exact at q={q}");
        }

        let mut equal = Histogram::new();
        for _ in 0..100 {
            equal.observe(1.25e-2);
        }
        assert_eq!(equal.quantile(0.5), 1.25e-2, "all-equal is exact");
        assert_eq!(equal.quantile(0.99), 1.25e-2);
        assert_eq!(equal.count(), 100);
    }

    #[test]
    fn histogram_orders_quantiles() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.observe(i as f64 * 1e-6);
        }
        let (p50, p95, p99) = (h.quantile(0.5), h.quantile(0.95), h.quantile(0.99));
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        assert!(p50 > 1e-4 && p50 < 1e-3, "p50 {p50} near the median");
        assert!(p99 <= h.max());
    }

    #[test]
    fn chrome_trace_is_well_formed() {
        let t = Tracer::new(2, 64, 1);
        t.set_iter(4);
        t.record(0, 0, 1000, 2500, 0, 0);
        t.record(1, 2, 2000, 9000, 4096, 0);
        let text = t.drain().chrome_trace_json();
        let j = crate::util::json::Json::parse(&text).unwrap();
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        let e = &events[1];
        assert_eq!(e.get("name").unwrap().as_str(), Some("comm-wait"));
        assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(e.get("pid").unwrap().as_usize(), Some(1));
        assert_eq!(e.get("tid").unwrap().as_usize(), Some(1));
        assert_eq!(e.get("ts").unwrap().as_f64(), Some(2.0));
        assert_eq!(e.get("dur").unwrap().as_f64(), Some(7.0));
        assert_eq!(e.get("args").unwrap().get("iter").unwrap().as_usize(), Some(4));
        assert_eq!(
            e.get("args").unwrap().get("bytes").unwrap().as_usize(),
            Some(4096)
        );
        assert_eq!(j.get("displayTimeUnit").unwrap().as_str(), Some("ms"));
    }

    #[test]
    fn detector_finds_planted_outlier() {
        let cfg = SlowdownConfig::default();
        // stable ~1 ms baseline with mild jitter, one 80 ms spike
        let mut series: Vec<f64> = (0..40)
            .map(|i| 1.0e-3 + 1.0e-5 * ((i * 7 % 11) as f64))
            .collect();
        series[23] = 8.0e-2;
        let hits = detect_outliers(&series, &cfg);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!(hits[0].0, 23);
        assert!(hits[0].1 > 0.9e-3 && hits[0].1 < 1.2e-3, "median {}", hits[0].1);
    }

    #[test]
    fn detector_is_silent_on_clean_series() {
        let cfg = SlowdownConfig::default();
        let series: Vec<f64> = (0..60)
            .map(|i| 1.0e-3 + 2.0e-4 * ((i * 13 % 17) as f64 / 17.0))
            .collect();
        assert!(detect_outliers(&series, &cfg).is_empty());
        // sub-floor spikes stay silent even when they dwarf the median
        let mut tiny = vec![1.0e-6; 30];
        tiny[20] = 9.0e-4; // 900x the median but under min_secs
        assert!(detect_outliers(&tiny, &cfg).is_empty());
    }

    #[test]
    fn detect_slowdowns_groups_by_rank_and_phase() {
        let t = Tracer::new(1, 4096, 0);
        // comm-wait: 1 ms per iteration, iteration 20 takes 50 ms
        for iter in 0..30u64 {
            t.set_iter(iter as usize);
            let start = iter * 1_000_000;
            let dur = if iter == 20 { 50_000_000 } else { 1_000_000 };
            t.record(0, 2, start, start + dur, 0, 0);
            // bulk is just as slow at iteration 20, but bulk is not a
            // wait phase — the detector must not scan it
            t.record(0, 1, start, start + dur, 0, 0);
        }
        let data = t.drain();
        let slow = detect_slowdowns(&data.spans, &SlowdownConfig::default());
        assert_eq!(slow.len(), 1, "{slow:?}");
        assert_eq!(slow[0].iter, 20);
        assert_eq!(slow[0].code, 2);
        assert_eq!(slow[0].rank, 0);
        let summary = slowdown_summary(&slow);
        assert!(summary.starts_with("{\"count\":1,"), "{summary}");
        crate::util::json::Json::parse(&summary).unwrap();
    }

    #[test]
    fn metrics_registry_round_trips() {
        let mut m = Metrics::new();
        m.counter("iterations", 40);
        m.counter("iterations", 2);
        m.gauge("rel_residual", 1.5e-9);
        for i in 1..=20 {
            m.observe("phase.comm-wait.seconds", i as f64 * 1e-4);
        }
        assert_eq!(m.get_counter("iterations"), 42);
        let text = m.to_json(&[]);
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(
            j.get("counters").unwrap().get("iterations").unwrap().as_usize(),
            Some(42)
        );
        assert_eq!(
            j.get("gauges").unwrap().get("rel_residual").unwrap().as_f64(),
            Some(1.5e-9)
        );
        let h = j
            .get("histograms")
            .unwrap()
            .get("phase.comm-wait.seconds")
            .unwrap();
        assert_eq!(h.get("count").unwrap().as_usize(), Some(20));
        let p50 = h.get("p50").unwrap().as_f64().unwrap();
        let p99 = h.get("p99").unwrap().as_f64().unwrap();
        assert!(p50 <= p99);
        assert_eq!(
            j.get("slowdowns").unwrap().get("count").unwrap().as_usize(),
            Some(0)
        );
    }

    #[test]
    fn merge_combines_ranks() {
        let t0 = Tracer::new(1, 8, 0);
        let t1 = Tracer::new(1, 8, 1);
        t0.record(0, 1, 0, 10, 0, 0);
        t1.record(0, 1, 5, 15, 0, 0);
        for i in 0..10u64 {
            t1.record(0, 5, i, i, 0, 0); // overflows the 8-slot ring
        }
        let merged = TraceData::merge(vec![t0.drain(), t1.drain()]);
        assert_eq!(merged.spans.len(), 9);
        assert_eq!(merged.dropped, 3);
        assert!(merged.spans.windows(2).all(|w| (w[0].rank, w[0].thread)
            <= (w[1].rank, w[1].thread)));
    }
}
