//! Performance models: the A64FX machine model, host calibration,
//! roofline / efficiency conversions (DESIGN.md sections 4, 10), and
//! the profiler-driven autotuner behind `lqcd tune`.

pub mod machine;
pub mod roofline;
pub mod telemetry;
pub mod tune;

pub use machine::{
    auto_solver_threads, auto_solver_threads_capped, auto_solver_threads_capped_for,
    auto_solver_threads_for, calibrate_host, triad_bw_gbs, triad_thread_sweep, A64fx,
    AutoThreadBound, HostCalibration, SATURATION_FRACTION,
};
pub use telemetry::{
    detect_outliers, detect_slowdowns, phase_series, slowdown_summary, span_label,
    Histogram, Metrics, Slowdown, SlowdownConfig, SpanRecord, TraceData, Tracer,
};
pub use tune::{
    resolve_knobs, run_tune, CacheLookup, ExplicitKnobs, HostFingerprint, KnobSource,
    Measurements, ResolvedKnobs, TuneCache, TuneChoice, TuneOptions, KNEE_FRACTION,
    TUNE_CACHE_VERSION,
};
