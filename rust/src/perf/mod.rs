//! Performance models: the A64FX machine model, host calibration, and
//! roofline / efficiency conversions (DESIGN.md sections 4, 10).

pub mod machine;
pub mod roofline;

pub use machine::{
    auto_solver_threads, auto_solver_threads_capped, auto_solver_threads_capped_for,
    auto_solver_threads_for, calibrate_host, A64fx, AutoThreadBound, HostCalibration,
};
