//! `lqcd tune`: profiler-driven autotuning of the hot-path knobs.
//!
//! The paper's central empirical lesson is that the right 2D SIMD
//! packing and thread layout are *not* predictable from first
//! principles — FAPP profiling (Figs. 8/9, Table 1) found slowdowns
//! pure modeling missed. This module turns that one-off exercise into
//! a standing measurement loop:
//!
//! 1. [`run_tune`] sweeps the three empirical knobs on the actual host —
//!    2D tiling shapes (the Table 1 `VLENX x VLENY` family at each
//!    supported VLEN), solver team sizes (locating the measured
//!    bandwidth-saturation knee instead of assuming `cores/2`), and the
//!    EO2 chunking of the distributed merge — timing real `Meo` /
//!    fused-CG applies and converting each to effective GB/s through
//!    the same [`crate::perf::roofline`] byte models the solver bench
//!    reports.
//! 2. [`choose`] reduces the measurements to a [`TuneChoice`]
//!    deterministically (no timestamps, no randomness: same
//!    measurements in, same cache JSON out).
//! 3. [`TuneCache`] persists the result per machine, keyed by a
//!    [`HostFingerprint`] (core count + calibrated-bandwidth class +
//!    lattice volume class), and the solve path resolves each knob as
//!    CLI/config override → tune cache → static heuristic via
//!    [`resolve_knobs`], recording which source won.
//!
//! Tuning only ever picks *which* measured-identical configuration
//! runs: every knob combination produces bitwise-identical residual
//! histories under the canonical-reduction contract (threads,
//! chunking) or is pinned equal to the explicit-knob run (tiling), so
//! the tuner can never change numerics — `tests/tune.rs` pins this.

use std::fmt;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::comm::run_world;
use crate::coordinator::operator::{LinearOperator, NativeMdagM, NativeMeo};
use crate::coordinator::{BarrierKind, DistHopping, Eo2Schedule, Phase, Profiler, Team};
use crate::field::{FermionField, GaugeField};
use crate::lattice::{Geometry, LatticeDims, Parity, Tiling};
use crate::perf::machine::HostCalibration;
use crate::perf::roofline;
use crate::solver::fused;
use crate::util::json::{Json, JsonWriter};
use crate::util::rng::Rng;

/// Bump when the cache layout or the meaning of a knob changes: an old
/// on-disk cache then invalidates as stale instead of mis-resolving.
pub const TUNE_CACHE_VERSION: u64 = 1;

/// A team size sits at the bandwidth "knee" once it reaches this
/// fraction of the best measured solve bandwidth — the smallest such
/// count wins, so the tuner never burns cores past saturation.
pub const KNEE_FRACTION: f64 = 0.92;

const KAPPA: f32 = 0.1;

// ---------------------------------------------------------------------
// fingerprint + cache
// ---------------------------------------------------------------------

/// What makes a tune result transferable: same core count, same
/// bandwidth class (log2 bucket of the saturated STREAM GB/s — ±1
/// bucket tolerated, absorbing run-to-run calibration jitter), same
/// lattice volume class (floor log2 of the local volume). The cache
/// *file name* is keyed by the two stable components (cores, volume
/// class) so a solve can locate the cache without paying a calibration
/// run; the bandwidth class is validated when one is available.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HostFingerprint {
    pub cores: usize,
    /// round(log2(saturated GB/s))
    pub bw_class: i64,
    /// floor(log2(local volume))
    pub volume_class: u32,
}

impl HostFingerprint {
    pub fn new(cores: usize, saturated_gbs: f64, dims: LatticeDims) -> HostFingerprint {
        HostFingerprint {
            cores: cores.max(1),
            bw_class: saturated_gbs.max(1e-3).log2().round() as i64,
            volume_class: volume_class(dims),
        }
    }

    /// Stable file-name key (the bandwidth class is intentionally NOT
    /// part of the key — see the struct docs).
    pub fn key(&self) -> String {
        format!("c{}-v{}", self.cores, self.volume_class)
    }

    /// Whether a cached fingerprint is still valid for this host.
    pub fn matches(&self, cached: &HostFingerprint) -> bool {
        self.cores == cached.cores
            && self.volume_class == cached.volume_class
            && (self.bw_class - cached.bw_class).abs() <= 1
    }
}

/// floor(log2(volume)) — lattices within a factor of 2 in volume share
/// tuning (the knee and best tile shape move with working-set size,
/// not with exact extents).
pub fn volume_class(dims: LatticeDims) -> u32 {
    let v = dims.volume().max(1);
    (usize::BITS - 1).saturating_sub(v.leading_zeros())
}

/// One timed tiling candidate (serial M-hat applies).
#[derive(Clone, Copy, Debug)]
pub struct TilingSample {
    pub tiling: Tiling,
    pub seconds_per_apply: f64,
    pub gbs: f64,
}

/// One timed team size (fused-CG iterations at the best tiling).
#[derive(Clone, Copy, Debug)]
pub struct ThreadSample {
    pub threads: usize,
    pub seconds_per_iter: f64,
    pub gbs: f64,
}

/// One timed EO2 chunking candidate (forced-comm distributed hopping).
#[derive(Clone, Copy, Debug)]
pub struct ChunkSample {
    pub schedule: Eo2Schedule,
    pub granularity: usize,
    pub seconds_per_apply: f64,
    pub eo2_imbalance: f64,
}

/// Everything the sweep measured. [`choose`] is a pure function of
/// this, so caching the measurements makes the choice reproducible.
#[derive(Clone, Debug)]
pub struct Measurements {
    pub dims: LatticeDims,
    pub stream_1t_gbs: f64,
    pub stream_sat_gbs: f64,
    pub tilings: Vec<TilingSample>,
    pub threads: Vec<ThreadSample>,
    pub chunks: Vec<ChunkSample>,
}

/// The tuned knob values plus the fitted roofline they came from.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TuneChoice {
    pub tiling: Tiling,
    pub threads: usize,
    pub eo2_schedule: Eo2Schedule,
    pub eo2_granularity: usize,
    /// best effective GB/s any swept configuration achieved — the
    /// fitted host roofline the bench's floor assertion measures
    /// against (falls back to the STREAM number when no kernel sample
    /// exists)
    pub roofline_gbs: f64,
}

/// Deterministic reduction of [`Measurements`] to a [`TuneChoice`]:
/// fastest tiling (ties go to the earlier candidate), smallest team
/// size within [`KNEE_FRACTION`] of the best solve bandwidth, fastest
/// EO2 chunking. Empty sweep sections fall back to the static
/// heuristics so a partial (`--quick`) tune still yields a usable
/// cache.
pub fn choose(m: &Measurements) -> TuneChoice {
    let tiling = m
        .tilings
        .iter()
        .fold(None::<TilingSample>, |best, &s| match best {
            Some(b) if b.gbs >= s.gbs => Some(b),
            _ => Some(s),
        })
        .map(|s| s.tiling)
        .unwrap_or_else(|| Tiling::new(4, 4).expect("static tiling"));

    let best_thread_gbs = m.threads.iter().map(|s| s.gbs).fold(0.0, f64::max);
    let threads = m
        .threads
        .iter()
        .filter(|s| s.gbs >= KNEE_FRACTION * best_thread_gbs)
        .map(|s| s.threads)
        .min()
        .unwrap_or(1);

    let (eo2_schedule, eo2_granularity) = m
        .chunks
        .iter()
        .fold(None::<ChunkSample>, |best, &s| match best {
            Some(b) if b.seconds_per_apply <= s.seconds_per_apply => Some(b),
            _ => Some(s),
        })
        .map(|s| (s.schedule, s.granularity))
        .unwrap_or((Eo2Schedule::Uniform, 1));

    let kernel_best = m
        .tilings
        .iter()
        .map(|s| s.gbs)
        .chain(m.threads.iter().map(|s| s.gbs))
        .fold(0.0, f64::max);
    let roofline_gbs = if kernel_best > 0.0 {
        kernel_best
    } else {
        m.stream_sat_gbs
    };

    TuneChoice {
        tiling,
        threads,
        eo2_schedule,
        eo2_granularity,
        roofline_gbs,
    }
}

/// The per-machine cache `lqcd tune` writes and `lqcd solve` consumes.
#[derive(Clone, Debug)]
pub struct TuneCache {
    pub version: u64,
    pub fingerprint: HostFingerprint,
    pub choice: TuneChoice,
    pub measurements: Measurements,
}

/// Outcome of a cache lookup — the solve path logs each variant
/// differently (hit, stale-refused, corrupt-warning, plain miss).
#[derive(Debug)]
pub enum CacheLookup {
    Hit(Box<TuneCache>),
    /// a cache exists but its version or fingerprint no longer matches
    Stale { found: String, want: String },
    /// a cache file exists but cannot be read or parsed
    Corrupt(String),
    Missing,
}

impl TuneCache {
    pub fn from_measurements(fingerprint: HostFingerprint, m: Measurements) -> TuneCache {
        TuneCache {
            version: TUNE_CACHE_VERSION,
            fingerprint,
            choice: choose(&m),
            measurements: m,
        }
    }

    /// Serialize. Key order, float formatting and array order are all
    /// fixed (the document streams through [`JsonWriter`] with the
    /// repo-wide `fnum` float convention), and nothing time- or
    /// run-dependent is recorded: identical measurements serialize to
    /// identical bytes (pinned by `tests/tune.rs`).
    pub fn to_json(&self) -> String {
        let fp = &self.fingerprint;
        let c = &self.choice;
        let m = &self.measurements;
        let d = m.dims;
        let mut w = JsonWriter::new();
        w.obj_begin();
        w.key("version");
        w.uint(self.version);
        w.key("fingerprint");
        w.obj_begin();
        w.key("cores");
        w.uint(fp.cores as u64);
        w.key("bw_class");
        w.int(fp.bw_class);
        w.key("volume_class");
        w.uint(u64::from(fp.volume_class));
        w.obj_end();
        w.key("choice");
        w.obj_begin();
        w.key("tiling");
        w.str_val(&c.tiling.to_string());
        w.key("threads");
        w.uint(c.threads as u64);
        w.key("eo2_schedule");
        w.str_val(&c.eo2_schedule.to_string());
        w.key("eo2_granularity");
        w.uint(c.eo2_granularity as u64);
        w.key("roofline_gbs");
        w.num(c.roofline_gbs);
        w.obj_end();
        w.key("measurements");
        w.obj_begin();
        w.key("dims");
        w.arr_begin();
        for v in [d.x, d.y, d.z, d.t] {
            w.uint(v as u64);
        }
        w.arr_end();
        w.key("stream_1t_gbs");
        w.num(m.stream_1t_gbs);
        w.key("stream_sat_gbs");
        w.num(m.stream_sat_gbs);
        w.key("tilings");
        w.arr_begin();
        for t in &m.tilings {
            w.obj_begin();
            w.key("tiling");
            w.str_val(&t.tiling.to_string());
            w.key("seconds_per_apply");
            w.num(t.seconds_per_apply);
            w.key("gbs");
            w.num(t.gbs);
            w.obj_end();
        }
        w.arr_end();
        w.key("threads");
        w.arr_begin();
        for t in &m.threads {
            w.obj_begin();
            w.key("threads");
            w.uint(t.threads as u64);
            w.key("seconds_per_iter");
            w.num(t.seconds_per_iter);
            w.key("gbs");
            w.num(t.gbs);
            w.obj_end();
        }
        w.arr_end();
        w.key("chunks");
        w.arr_begin();
        for t in &m.chunks {
            w.obj_begin();
            w.key("schedule");
            w.str_val(&t.schedule.to_string());
            w.key("granularity");
            w.uint(t.granularity as u64);
            w.key("seconds_per_apply");
            w.num(t.seconds_per_apply);
            w.key("eo2_imbalance");
            w.num(t.eo2_imbalance);
            w.obj_end();
        }
        w.arr_end();
        w.obj_end();
        w.obj_end();
        w.finish()
    }

    /// Parse a cache document (strict: any missing or mistyped field is
    /// an error, so a truncated file surfaces as [`CacheLookup::Corrupt`]
    /// rather than as half-applied knobs).
    pub fn parse(text: &str) -> Result<TuneCache, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let version = get_u64(&j, "version")?;
        let fpj = j.get("fingerprint").ok_or("missing fingerprint")?;
        let fingerprint = HostFingerprint {
            cores: get_u64(fpj, "cores")? as usize,
            bw_class: get_f64(fpj, "bw_class")? as i64,
            volume_class: get_u64(fpj, "volume_class")? as u32,
        };
        let cj = j.get("choice").ok_or("missing choice")?;
        let choice = TuneChoice {
            tiling: Tiling::parse(get_str(cj, "tiling")?)?,
            threads: (get_u64(cj, "threads")? as usize).max(1),
            eo2_schedule: Eo2Schedule::parse(get_str(cj, "eo2_schedule")?)?,
            eo2_granularity: (get_u64(cj, "eo2_granularity")? as usize).max(1),
            roofline_gbs: get_f64(cj, "roofline_gbs")?,
        };
        let mj = j.get("measurements").ok_or("missing measurements")?;
        let dims_arr = mj
            .get("dims")
            .and_then(Json::as_arr)
            .ok_or("missing dims")?;
        if dims_arr.len() != 4 {
            return Err("dims must have 4 entries".into());
        }
        let dv: Vec<usize> = dims_arr.iter().filter_map(Json::as_usize).collect();
        if dv.len() != 4 {
            return Err("dims entries must be numbers".into());
        }
        let dims = LatticeDims::new(dv[0], dv[1], dv[2], dv[3]).map_err(|e| e.to_string())?;
        let mut tilings = Vec::new();
        for t in mj
            .get("tilings")
            .and_then(Json::as_arr)
            .ok_or("missing tilings")?
        {
            tilings.push(TilingSample {
                tiling: Tiling::parse(get_str(t, "tiling")?)?,
                seconds_per_apply: get_f64(t, "seconds_per_apply")?,
                gbs: get_f64(t, "gbs")?,
            });
        }
        let mut threads = Vec::new();
        for t in mj
            .get("threads")
            .and_then(Json::as_arr)
            .ok_or("missing threads")?
        {
            threads.push(ThreadSample {
                threads: (get_u64(t, "threads")? as usize).max(1),
                seconds_per_iter: get_f64(t, "seconds_per_iter")?,
                gbs: get_f64(t, "gbs")?,
            });
        }
        let mut chunks = Vec::new();
        for t in mj
            .get("chunks")
            .and_then(Json::as_arr)
            .ok_or("missing chunks")?
        {
            chunks.push(ChunkSample {
                schedule: Eo2Schedule::parse(get_str(t, "schedule")?)?,
                granularity: (get_u64(t, "granularity")? as usize).max(1),
                seconds_per_apply: get_f64(t, "seconds_per_apply")?,
                eo2_imbalance: get_f64(t, "eo2_imbalance")?,
            });
        }
        Ok(TuneCache {
            version,
            fingerprint,
            choice,
            measurements: Measurements {
                dims,
                stream_1t_gbs: get_f64(mj, "stream_1t_gbs")?,
                stream_sat_gbs: get_f64(mj, "stream_sat_gbs")?,
                tilings,
                threads,
                chunks,
            },
        })
    }

    /// File this cache lives in under `dir`.
    pub fn path_in(&self, dir: &Path) -> PathBuf {
        dir.join(format!("tune-{}.json", self.fingerprint.key()))
    }

    /// Write the cache under `dir` (created if needed); returns the path.
    pub fn save(&self, dir: &Path) -> std::io::Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = self.path_in(dir);
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }

    /// Strict lookup: version AND full fingerprint (including the ±1
    /// bandwidth-class tolerance) must match.
    pub fn load_for(dir: &Path, fp: &HostFingerprint) -> CacheLookup {
        Self::load_checked(dir, &fp.key(), |cached| {
            if fp.matches(&cached.fingerprint) {
                None
            } else {
                Some((format!("{:?}", cached.fingerprint), format!("{fp:?}")))
            }
        })
    }

    /// Solve-path lookup: keyed by (cores, volume class) only, so a
    /// solve never pays a calibration run just to read its knobs. The
    /// stored bandwidth class is accepted as-is — `lqcd tune` validated
    /// it when the cache was written.
    pub fn load_for_host(dir: &Path, cores: usize, dims: LatticeDims) -> CacheLookup {
        let cores = cores.max(1);
        let vclass = volume_class(dims);
        let key = format!("c{cores}-v{vclass}");
        Self::load_checked(dir, &key, |cached| {
            if cached.fingerprint.cores == cores && cached.fingerprint.volume_class == vclass {
                None
            } else {
                Some((
                    format!("{:?}", cached.fingerprint),
                    format!("cores {cores}, volume_class {vclass}"),
                ))
            }
        })
    }

    fn load_checked(
        dir: &Path,
        key: &str,
        mismatch: impl Fn(&TuneCache) -> Option<(String, String)>,
    ) -> CacheLookup {
        let path = dir.join(format!("tune-{key}.json"));
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return CacheLookup::Missing,
            Err(e) => return CacheLookup::Corrupt(format!("{}: {e}", path.display())),
        };
        let cache = match TuneCache::parse(&text) {
            Ok(c) => c,
            Err(e) => return CacheLookup::Corrupt(format!("{}: {e}", path.display())),
        };
        if cache.version != TUNE_CACHE_VERSION {
            return CacheLookup::Stale {
                found: format!("version {}", cache.version),
                want: format!("version {TUNE_CACHE_VERSION}"),
            };
        }
        match mismatch(&cache) {
            Some((found, want)) => CacheLookup::Stale { found, want },
            None => CacheLookup::Hit(Box::new(cache)),
        }
    }
}


fn get_f64(j: &Json, key: &str) -> Result<f64, String> {
    j.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("missing number {key:?}"))
}

fn get_u64(j: &Json, key: &str) -> Result<u64, String> {
    let v = get_f64(j, key)?;
    if v < 0.0 {
        return Err(format!("{key:?} must be non-negative"));
    }
    Ok(v as u64)
}

fn get_str<'a>(j: &'a Json, key: &str) -> Result<&'a str, String> {
    j.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| format!("missing string {key:?}"))
}

// ---------------------------------------------------------------------
// knob resolution
// ---------------------------------------------------------------------

/// Where a resolved knob value came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KnobSource {
    /// explicit CLI option or config key — always wins
    Cli,
    /// the per-machine tune cache
    Cache,
    /// the static in-code heuristic (the pre-tuning behavior)
    Heuristic,
}

impl fmt::Display for KnobSource {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            KnobSource::Cli => "cli/config",
            KnobSource::Cache => "tune-cache",
            KnobSource::Heuristic => "heuristic",
        })
    }
}

/// Knobs the user pinned explicitly (CLI option or config key). `None`
/// means "let the cache or the heuristic decide".
#[derive(Clone, Copy, Debug, Default)]
pub struct ExplicitKnobs {
    pub tiling: Option<Tiling>,
    pub threads: Option<usize>,
    pub eo2_schedule: Option<Eo2Schedule>,
    pub eo2_granularity: Option<usize>,
}

/// The resolved knob set: each value tagged with the source that won.
#[derive(Clone, Copy, Debug)]
pub struct ResolvedKnobs {
    pub tiling: (Tiling, KnobSource),
    pub threads: (usize, KnobSource),
    pub eo2_schedule: (Eo2Schedule, KnobSource),
    pub eo2_granularity: (usize, KnobSource),
}

impl ResolvedKnobs {
    /// One-line per-knob provenance, logged by the solve and recorded
    /// in `SolveStats::knob_sources`.
    pub fn summary(&self) -> String {
        format!(
            "tiling={}[{}] threads={}[{}] eo2-schedule={}[{}] eo2-granularity={}[{}]",
            self.tiling.0,
            self.tiling.1,
            self.threads.0,
            self.threads.1,
            self.eo2_schedule.0,
            self.eo2_schedule.1,
            self.eo2_granularity.0,
            self.eo2_granularity.1,
        )
    }
}

/// Resolve every knob as CLI/config → tune cache → static heuristic.
/// A cached tiling that does not divide the local lattice (tuned at a
/// different shape within the same volume class) is skipped, not
/// force-fed: the heuristic takes over for that knob only.
pub fn resolve_knobs(
    explicit: &ExplicitKnobs,
    cache: Option<&TuneCache>,
    local_dims: LatticeDims,
    heuristic_tiling: Tiling,
    heuristic_threads: usize,
) -> ResolvedKnobs {
    let choice = cache.map(|c| c.choice);
    let tiling = if let Some(t) = explicit.tiling {
        (t, KnobSource::Cli)
    } else if let Some(c) = choice.filter(|c| c.tiling.divides(local_dims)) {
        (c.tiling, KnobSource::Cache)
    } else {
        (heuristic_tiling, KnobSource::Heuristic)
    };
    let threads = if let Some(t) = explicit.threads {
        (t.max(1), KnobSource::Cli)
    } else if let Some(c) = choice {
        (c.threads.max(1), KnobSource::Cache)
    } else {
        (heuristic_threads.max(1), KnobSource::Heuristic)
    };
    let eo2_schedule = if let Some(s) = explicit.eo2_schedule {
        (s, KnobSource::Cli)
    } else if let Some(c) = choice {
        (c.eo2_schedule, KnobSource::Cache)
    } else {
        (Eo2Schedule::Uniform, KnobSource::Heuristic)
    };
    let eo2_granularity = if let Some(g) = explicit.eo2_granularity {
        (g.max(1), KnobSource::Cli)
    } else if let Some(c) = choice {
        (c.eo2_granularity.max(1), KnobSource::Cache)
    } else {
        (1, KnobSource::Heuristic)
    };
    ResolvedKnobs {
        tiling,
        threads,
        eo2_schedule,
        eo2_granularity,
    }
}

// ---------------------------------------------------------------------
// the sweep
// ---------------------------------------------------------------------

/// Sweep parameters for [`run_tune`].
#[derive(Clone, Copy, Debug)]
pub struct TuneOptions {
    pub dims: LatticeDims,
    pub seed: u64,
    /// total wall budget, split across the three sweeps
    pub budget_ms: u64,
    /// `--quick`: CI smoke mode — one VLEN family, two team sizes, two
    /// chunkings; seconds not minutes
    pub quick: bool,
}

/// Tiling candidates: every legal `VLENX x VLENY` shape of each
/// supported VLEN family that divides the local lattice. `--quick`
/// sweeps only the paper's VLEN = 16 family.
pub fn candidate_tilings(dims: LatticeDims, quick: bool) -> Vec<Tiling> {
    let vlens: &[usize] = if quick { &[16] } else { &[4, 8, 16] };
    let mut out: Vec<Tiling> = Vec::new();
    for &v in vlens {
        for t in Tiling::sweep_for_vlen(v) {
            if t.divides(dims) && !out.contains(&t) {
                out.push(t);
            }
        }
    }
    out
}

/// Team sizes to time: the doubling sweep of
/// [`crate::perf::machine::triad_thread_sweep`] plus the `cores/2`
/// heuristic point, so the measured knee is always comparable to the
/// static guess. `--quick` times just 1 and `cores/2`.
pub fn candidate_threads(cores: usize, quick: bool) -> Vec<usize> {
    let cores = cores.max(1);
    let mut counts = if quick {
        vec![1, (cores / 2).max(1)]
    } else {
        let mut c = crate::perf::machine::triad_thread_sweep(cores);
        c.push((cores / 2).max(1));
        c
    };
    counts.sort_unstable();
    counts.dedup();
    counts
}

/// EO2 chunking candidates (schedule, boundary granularity in sites).
pub fn candidate_chunkings(quick: bool) -> Vec<(Eo2Schedule, usize)> {
    if quick {
        vec![(Eo2Schedule::Uniform, 1), (Eo2Schedule::Balanced, 1)]
    } else {
        vec![
            (Eo2Schedule::Uniform, 1),
            (Eo2Schedule::Balanced, 1),
            (Eo2Schedule::Balanced, 4),
            (Eo2Schedule::Balanced, 16),
        ]
    }
}

/// Repetitions that fit a per-candidate budget given one pilot timing.
fn reps_for_budget(budget_secs: f64, pilot_secs: f64) -> usize {
    ((budget_secs / pilot_secs.max(1e-9)) as usize).clamp(2, 40)
}

/// Run the three sweeps and return the raw measurements. Deterministic
/// in everything but the timings themselves: field content comes from
/// the seeded RNG, candidate order is fixed, and the arithmetic of
/// every timed apply is the production kernel's (the tuner measures
/// the real code path, not a proxy).
pub fn run_tune(host: &HostCalibration, opts: &TuneOptions) -> Measurements {
    let dims = opts.dims;
    let budget = opts.budget_ms as f64 / 1e3;
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    // ---- sweep (a): tiling shapes, serial M-hat applies --------------
    let tilings = candidate_tilings(dims, opts.quick);
    let per_tiling = budget / 3.0 / tilings.len().max(1) as f64;
    let mut tiling_samples = Vec::with_capacity(tilings.len());
    for &t in &tilings {
        let geom = match Geometry::single_rank(dims, t) {
            Ok(g) => g,
            Err(_) => continue,
        };
        let mut rng = Rng::seeded(opts.seed);
        let u = GaugeField::<f32>::random(&geom, &mut rng);
        let psi = FermionField::<f32>::gaussian(&geom, &mut rng);
        let mut out = psi.zeros_like();
        let mut op = NativeMeo::new(&geom, u, KAPPA);
        let bytes = roofline::meo_apply_bytes(&geom, 4, 18);
        let t0 = Instant::now();
        op.apply(&mut out, &psi);
        let pilot = t0.elapsed().as_secs_f64();
        let reps = reps_for_budget(per_tiling, pilot);
        let t0 = Instant::now();
        for _ in 0..reps {
            op.apply(&mut out, &psi);
        }
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        std::hint::black_box(out.data[0]);
        tiling_samples.push(TilingSample {
            tiling: t,
            seconds_per_apply: secs / reps as f64,
            gbs: bytes as f64 * reps as f64 / secs / 1e9,
        });
    }

    // ---- sweep (b): team sizes, fused-CG iterations ------------------
    let best_tiling = tiling_samples
        .iter()
        .fold(None::<TilingSample>, |best, &s| match best {
            Some(b) if b.gbs >= s.gbs => Some(b),
            _ => Some(s),
        })
        .map(|s| s.tiling)
        .unwrap_or_else(|| Tiling::new(4, 4).expect("static tiling"));
    let thread_counts = candidate_threads(cores, opts.quick);
    let per_thread = budget / 3.0 / thread_counts.len().max(1) as f64;
    let mut thread_samples = Vec::with_capacity(thread_counts.len());
    if let Ok(geom) = Geometry::single_rank(dims, best_tiling) {
        let iter_bytes = roofline::cg_iter_bytes(&geom, 4, true);
        for &n in &thread_counts {
            let mut rng = Rng::seeded(opts.seed);
            let u = GaugeField::<f32>::random(&geom, &mut rng);
            let b = FermionField::<f32>::gaussian(&geom, &mut rng);
            let mut x = b.zeros_like();
            let mut op = NativeMdagM::new(&geom, u, KAPPA);
            let mut team = Team::new(n, BarrierKind::Spin);
            // tol = 0 keeps CG running for exactly `maxiter` iterations
            let t0 = Instant::now();
            fused::cg(&mut op, &mut team, &mut x, &b, 0.0, 1);
            let pilot = t0.elapsed().as_secs_f64();
            let iters = reps_for_budget(per_thread, pilot);
            x.fill(0.0);
            let t0 = Instant::now();
            let stats = fused::cg(&mut op, &mut team, &mut x, &b, 0.0, iters);
            let secs = t0.elapsed().as_secs_f64().max(1e-9);
            let done = stats.iterations.max(1);
            thread_samples.push(ThreadSample {
                threads: n,
                seconds_per_iter: secs / done as f64,
                gbs: iter_bytes as f64 * done as f64 / secs / 1e9,
            });
        }
    }

    // ---- sweep (c): EO2 chunking, forced-comm distributed hopping ----
    let knee = {
        let best = thread_samples.iter().map(|s| s.gbs).fold(0.0, f64::max);
        thread_samples
            .iter()
            .filter(|s| s.gbs >= KNEE_FRACTION * best)
            .map(|s| s.threads)
            .min()
            .unwrap_or(1)
    };
    let chunkings = candidate_chunkings(opts.quick);
    let per_chunk = budget / 3.0 / chunkings.len().max(1) as f64;
    let seed = opts.seed;
    let chunk_samples: Vec<ChunkSample> = if Geometry::single_rank(dims, best_tiling).is_ok() {
        run_world(1, |_rank, comm| {
            let geom = Geometry::single_rank(dims, best_tiling).expect("validated above");
            let mut rng = Rng::seeded(seed);
            let u = GaugeField::<f32>::random(&geom, &mut rng);
            let psi = FermionField::<f32>::gaussian(&geom, &mut rng);
            let mut out = psi.zeros_like();
            let mut samples = Vec::with_capacity(chunkings.len());
            for &(schedule, granularity) in &chunkings {
                let hop =
                    DistHopping::with_chunking(&geom, true, knee, schedule, granularity);
                let mut team = Team::new(knee, BarrierKind::Spin);
                let prof = Profiler::new(knee);
                let t0 = Instant::now();
                hop.hopping(&mut out, &u, &psi, Parity::Even, comm, &mut team, &prof);
                let pilot = t0.elapsed().as_secs_f64();
                let reps = reps_for_budget(per_chunk, pilot);
                prof.reset();
                let t0 = Instant::now();
                for _ in 0..reps {
                    hop.hopping(&mut out, &u, &psi, Parity::Even, comm, &mut team, &prof);
                }
                let secs = t0.elapsed().as_secs_f64().max(1e-9);
                std::hint::black_box(out.data[0]);
                samples.push(ChunkSample {
                    schedule,
                    granularity,
                    seconds_per_apply: secs / reps as f64,
                    eo2_imbalance: prof.snapshot().imbalance(Phase::Eo2),
                });
            }
            samples
        })
        .pop()
        .unwrap_or_default()
    } else {
        Vec::new()
    };

    Measurements {
        dims,
        stream_1t_gbs: host.mem_bw_gbs,
        stream_sat_gbs: host.mem_bw_saturated_gbs,
        tilings: tiling_samples,
        threads: thread_samples,
        chunks: chunk_samples,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> LatticeDims {
        LatticeDims::new(8, 8, 4, 4).unwrap()
    }

    fn sample_measurements() -> Measurements {
        Measurements {
            dims: dims(),
            stream_1t_gbs: 10.0,
            stream_sat_gbs: 30.0,
            tilings: vec![
                TilingSample {
                    tiling: Tiling::new(4, 4).unwrap(),
                    seconds_per_apply: 1e-3,
                    gbs: 20.0,
                },
                TilingSample {
                    tiling: Tiling::new(2, 2).unwrap(),
                    seconds_per_apply: 2e-3,
                    gbs: 10.0,
                },
            ],
            threads: vec![
                ThreadSample {
                    threads: 1,
                    seconds_per_iter: 4e-3,
                    gbs: 10.0,
                },
                ThreadSample {
                    threads: 2,
                    seconds_per_iter: 2.1e-3,
                    gbs: 19.5,
                },
                ThreadSample {
                    threads: 4,
                    seconds_per_iter: 2e-3,
                    gbs: 20.0,
                },
            ],
            chunks: vec![
                ChunkSample {
                    schedule: Eo2Schedule::Uniform,
                    granularity: 1,
                    seconds_per_apply: 3e-3,
                    eo2_imbalance: 2.0,
                },
                ChunkSample {
                    schedule: Eo2Schedule::Balanced,
                    granularity: 4,
                    seconds_per_apply: 2.5e-3,
                    eo2_imbalance: 1.1,
                },
            ],
        }
    }

    #[test]
    fn choose_picks_knee_not_max() {
        let c = choose(&sample_measurements());
        assert_eq!(c.tiling, Tiling::new(4, 4).unwrap());
        // 2 threads reach 19.5/20.0 = 97.5% > KNEE_FRACTION: knee is 2
        assert_eq!(c.threads, 2);
        assert_eq!(c.eo2_schedule, Eo2Schedule::Balanced);
        assert_eq!(c.eo2_granularity, 4);
        assert!((c.roofline_gbs - 20.0).abs() < 1e-12);
    }

    #[test]
    fn choose_falls_back_on_empty_sweeps() {
        let m = Measurements {
            dims: dims(),
            stream_1t_gbs: 5.0,
            stream_sat_gbs: 12.0,
            tilings: vec![],
            threads: vec![],
            chunks: vec![],
        };
        let c = choose(&m);
        assert_eq!(c.tiling, Tiling::new(4, 4).unwrap());
        assert_eq!(c.threads, 1);
        assert_eq!(c.eo2_schedule, Eo2Schedule::Uniform);
        assert_eq!(c.eo2_granularity, 1);
        assert!((c.roofline_gbs - 12.0).abs() < 1e-12);
    }

    #[test]
    fn fingerprint_key_and_tolerance() {
        let d = dims();
        let fp = HostFingerprint::new(8, 20.0, d);
        assert_eq!(fp.key(), format!("c8-v{}", volume_class(d)));
        // same bucket
        assert!(fp.matches(&HostFingerprint::new(8, 21.0, d)));
        // one bucket off is tolerated (calibration jitter)
        assert!(fp.matches(&HostFingerprint::new(8, 40.0, d)));
        // four buckets off is a different machine class
        assert!(!fp.matches(&HostFingerprint::new(8, 320.0, d)));
        // core count is strict
        assert!(!fp.matches(&HostFingerprint::new(4, 20.0, d)));
    }

    #[test]
    fn volume_class_doubles() {
        let a = volume_class(LatticeDims::new(8, 8, 8, 8).unwrap()); // 4096
        let b = volume_class(LatticeDims::new(8, 8, 8, 16).unwrap()); // 8192
        assert_eq!(a, 12);
        assert_eq!(b, 13);
    }

    #[test]
    fn cache_roundtrip() {
        let fp = HostFingerprint::new(8, 20.0, dims());
        let cache = TuneCache::from_measurements(fp, sample_measurements());
        let parsed = TuneCache::parse(&cache.to_json()).unwrap();
        assert_eq!(parsed.version, TUNE_CACHE_VERSION);
        assert_eq!(parsed.fingerprint, fp);
        assert_eq!(parsed.choice, cache.choice);
        assert_eq!(parsed.measurements.tilings.len(), 2);
        assert_eq!(parsed.measurements.threads.len(), 3);
        assert_eq!(parsed.measurements.chunks.len(), 2);
        // serialization is a fixed point after one roundtrip
        assert_eq!(parsed.to_json(), cache.to_json());
    }

    #[test]
    fn candidate_tilings_all_divide() {
        let d = LatticeDims::new(8, 4, 4, 4).unwrap(); // xh = 4
        for quick in [false, true] {
            let c = candidate_tilings(d, quick);
            assert!(!c.is_empty());
            assert!(c.iter().all(|t| t.divides(d)), "{c:?}");
        }
        // quick restricts to the VLEN=16 family
        assert!(candidate_tilings(d, true).iter().all(|t| t.vlen() == 16));
    }

    #[test]
    fn candidate_threads_include_heuristic_point() {
        let c = candidate_threads(48, false);
        assert!(c.contains(&1));
        assert!(c.contains(&24), "{c:?}"); // 48/2
        assert!(c.windows(2).all(|w| w[0] < w[1]), "sorted unique: {c:?}");
        assert_eq!(candidate_threads(8, true), vec![1, 4]);
        assert_eq!(candidate_threads(1, true), vec![1]);
    }

    #[test]
    fn resolution_precedence() {
        let d = dims();
        let cache =
            TuneCache::from_measurements(HostFingerprint::new(8, 20.0, d), sample_measurements());
        let h_tiling = Tiling::new(2, 2).unwrap();
        // no explicit, cache present: cache wins everywhere
        let r = resolve_knobs(&ExplicitKnobs::default(), Some(&cache), d, h_tiling, 3);
        assert_eq!(r.tiling, (Tiling::new(4, 4).unwrap(), KnobSource::Cache));
        assert_eq!(r.threads, (2, KnobSource::Cache));
        assert_eq!(r.eo2_schedule, (Eo2Schedule::Balanced, KnobSource::Cache));
        assert_eq!(r.eo2_granularity, (4, KnobSource::Cache));
        // explicit beats cache
        let e = ExplicitKnobs {
            tiling: Some(Tiling::new(2, 8).unwrap()),
            threads: Some(7),
            eo2_schedule: Some(Eo2Schedule::Uniform),
            eo2_granularity: Some(2),
        };
        let r = resolve_knobs(&e, Some(&cache), d, h_tiling, 3);
        assert_eq!(r.tiling, (Tiling::new(2, 8).unwrap(), KnobSource::Cli));
        assert_eq!(r.threads, (7, KnobSource::Cli));
        assert_eq!(r.eo2_schedule, (Eo2Schedule::Uniform, KnobSource::Cli));
        assert_eq!(r.eo2_granularity, (2, KnobSource::Cli));
        // no cache: heuristic
        let r = resolve_knobs(&ExplicitKnobs::default(), None, d, h_tiling, 3);
        assert_eq!(r.tiling, (h_tiling, KnobSource::Heuristic));
        assert_eq!(r.threads, (3, KnobSource::Heuristic));
        assert_eq!(r.eo2_schedule, (Eo2Schedule::Uniform, KnobSource::Heuristic));
        assert_eq!(r.eo2_granularity, (1, KnobSource::Heuristic));
    }

    #[test]
    fn cached_tiling_that_does_not_divide_falls_back() {
        // tune at 8x8x4x4 chose 4x4; this lattice has xh = 2 so the
        // cached tiling cannot be laid out — heuristic takes that knob,
        // the cache keeps the others
        let d = LatticeDims::new(4, 8, 4, 8).unwrap();
        let cache = TuneCache::from_measurements(
            HostFingerprint::new(8, 20.0, dims()),
            sample_measurements(),
        );
        let h_tiling = Tiling::new(2, 2).unwrap();
        let r = resolve_knobs(&ExplicitKnobs::default(), Some(&cache), d, h_tiling, 3);
        assert_eq!(r.tiling, (h_tiling, KnobSource::Heuristic));
        assert_eq!(r.threads, (2, KnobSource::Cache));
    }

    #[test]
    fn summary_names_every_source() {
        let d = dims();
        let r = resolve_knobs(
            &ExplicitKnobs {
                threads: Some(2),
                ..Default::default()
            },
            None,
            d,
            Tiling::new(4, 4).unwrap(),
            1,
        );
        let s = r.summary();
        assert!(s.contains("tiling=4x4[heuristic]"), "{s}");
        assert!(s.contains("threads=2[cli/config]"), "{s}");
        assert!(s.contains("eo2-schedule=uniform[heuristic]"), "{s}");
        assert!(s.contains("eo2-granularity=1[heuristic]"), "{s}");
    }
}
