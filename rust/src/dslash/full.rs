//! Full Wilson matrix `D_W = 1 - kappa H` on an (even, odd) field pair,
//! plus the even-odd preconditioned operator M-hat (Eq. 4) and the odd
//! reconstruction (Eq. 5), generic over the hopping implementation's
//! field precision: `kappa` and all temporaries follow the field scalar
//! `R`, so the same compositions serve the f32 hot path and the f64
//! outer solve.

use crate::algebra::Real;
use crate::field::{FermionField, GaugeField};
use crate::lattice::Parity;

use super::eo::HoppingEo;

/// out_e = psi_e - kappa * H_eo psi_o,  out_o = psi_o - kappa * H_oe psi_e.
pub fn dslash_full<R: Real>(
    hop: &HoppingEo,
    out_e: &mut FermionField<R>,
    out_o: &mut FermionField<R>,
    u: &GaugeField<R>,
    psi_e: &FermionField<R>,
    psi_o: &FermionField<R>,
    kappa: R,
) {
    hop.apply(out_e, u, psi_o, Parity::Even);
    out_e.xpay(-kappa, psi_e);
    hop.apply(out_o, u, psi_e, Parity::Odd);
    out_o.xpay(-kappa, psi_o);
}

/// The even-odd preconditioned operator (Eq. 4 LHS):
/// out = psi - kappa^2 H_eo H_oe psi  (psi lives on even sites).
/// `tmp` is odd-parity scratch.
pub fn meo<R: Real>(
    hop: &HoppingEo,
    out: &mut FermionField<R>,
    tmp: &mut FermionField<R>,
    u: &GaugeField<R>,
    psi: &FermionField<R>,
    kappa: R,
) {
    hop.apply(tmp, u, psi, Parity::Odd);
    hop.apply(out, u, tmp, Parity::Even);
    out.xpay(-(kappa * kappa), psi);
}

/// M-hat^dagger = gamma5 M-hat gamma5.
pub fn meo_dag<R: Real>(
    hop: &HoppingEo,
    out: &mut FermionField<R>,
    tmp: &mut FermionField<R>,
    u: &GaugeField<R>,
    psi: &FermionField<R>,
    kappa: R,
) {
    let mut g5psi = psi.clone();
    g5psi.gamma5();
    meo(hop, out, tmp, u, &g5psi, kappa);
    out.gamma5();
}

/// Eq. 5: xi_o = eta_o + kappa H_oe xi_e (D_oo = 1 for Wilson).
pub fn reconstruct_odd<R: Real>(
    hop: &HoppingEo,
    out: &mut FermionField<R>,
    u: &GaugeField<R>,
    eta_o: &FermionField<R>,
    xi_e: &FermionField<R>,
    kappa: R,
) {
    hop.apply(out, u, xi_e, Parity::Odd);
    out.scale(kappa);
    out.axpy(R::ONE, eta_o);
}

/// rhs of Eq. 4: b = eta_e + kappa H_eo eta_o (D_oo^-1 = 1).
pub fn schur_rhs<R: Real>(
    hop: &HoppingEo,
    out: &mut FermionField<R>,
    u: &GaugeField<R>,
    eta_e: &FermionField<R>,
    eta_o: &FermionField<R>,
    kappa: R,
) {
    hop.apply(out, u, eta_o, Parity::Even);
    out.scale(kappa);
    out.axpy(R::ONE, eta_e);
}
