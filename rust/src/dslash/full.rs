//! Full Wilson matrix `D_W = 1 - kappa H` on an (even, odd) field pair,
//! plus the even-odd preconditioned operator M-hat (Eq. 4) and the odd
//! reconstruction (Eq. 5), generic over any hopping implementation.

use crate::field::{FermionField, GaugeField};
use crate::lattice::Parity;

use super::eo::HoppingEo;

/// out_e = psi_e - kappa * H_eo psi_o,  out_o = psi_o - kappa * H_oe psi_e.
pub fn dslash_full(
    hop: &HoppingEo,
    out_e: &mut FermionField,
    out_o: &mut FermionField,
    u: &GaugeField,
    psi_e: &FermionField,
    psi_o: &FermionField,
    kappa: f32,
) {
    hop.apply(out_e, u, psi_o, Parity::Even);
    out_e.xpay(-kappa, psi_e);
    hop.apply(out_o, u, psi_e, Parity::Odd);
    out_o.xpay(-kappa, psi_o);
}

/// The even-odd preconditioned operator (Eq. 4 LHS):
/// out = psi - kappa^2 H_eo H_oe psi  (psi lives on even sites).
/// `tmp` is odd-parity scratch.
pub fn meo(
    hop: &HoppingEo,
    out: &mut FermionField,
    tmp: &mut FermionField,
    u: &GaugeField,
    psi: &FermionField,
    kappa: f32,
) {
    hop.apply(tmp, u, psi, Parity::Odd);
    hop.apply(out, u, tmp, Parity::Even);
    out.xpay(-(kappa * kappa), psi);
}

/// M-hat^dagger = gamma5 M-hat gamma5.
pub fn meo_dag(
    hop: &HoppingEo,
    out: &mut FermionField,
    tmp: &mut FermionField,
    u: &GaugeField,
    psi: &FermionField,
    kappa: f32,
) {
    let mut g5psi = psi.clone();
    g5psi.gamma5();
    meo(hop, out, tmp, u, &g5psi, kappa);
    out.gamma5();
}

/// Eq. 5: xi_o = eta_o + kappa H_oe xi_e (D_oo = 1 for Wilson).
pub fn reconstruct_odd(
    hop: &HoppingEo,
    out: &mut FermionField,
    u: &GaugeField,
    eta_o: &FermionField,
    xi_e: &FermionField,
    kappa: f32,
) {
    hop.apply(out, u, xi_e, Parity::Odd);
    out.scale(kappa);
    out.axpy(1.0, eta_o);
}

/// rhs of Eq. 4: b = eta_e + kappa H_eo eta_o (D_oo^-1 = 1).
pub fn schur_rhs(
    hop: &HoppingEo,
    out: &mut FermionField,
    u: &GaugeField,
    eta_e: &FermionField,
    eta_o: &FermionField,
    kappa: f32,
) {
    hop.apply(out, u, eta_o, Parity::Even);
    out.scale(kappa);
    out.axpy(1.0, eta_e);
}
