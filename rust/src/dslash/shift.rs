//! Lane shuffle engine: the SVE `sel`/`tbl`/`ext` analogs (paper §3.4,
//! Figs. 5-6).
//!
//! A SIMD vector holds a `VLENX x VLENY` tile of the x-compacted x-y
//! plane (lane = `ly*VLENX + lx`). Neighbor access in x/y needs data from
//! two tiles merged into one vector:
//!
//! * **x-direction** (Fig. 5): on the compacted arrays the `+-x` neighbor
//!   of compact index `ix` is `ix + phi` / `ix - (1 - phi)` where
//!   `phi = (y+z+t+p_out) mod 2` is the *row* parity — so each lane row
//!   shifts by 0 or 1 depending on its parity. SVE does this with a
//!   predicated `sel` of the current/neighbor loads followed by a `tbl`
//!   permute; here the same merge+permute is a precomputed [`LanePlan`].
//! * **y-direction** (Fig. 6): all rows shift by one, i.e. an `ext`
//!   (concatenate two vectors, extract a window).
//! * **z/t**: whole-tile strides, no lane shuffle at all.
//!
//! Plans also carry the *boundary mask*: the lanes whose neighbor lives on
//! another rank. In `SkipBoundary` mode those lanes are zeroed (their
//! contribution arrives through the EO1/EO2 communication path instead).

use crate::algebra::Real;
use crate::lattice::Tiling;

/// Which source vector a lane reads from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Src {
    /// the current tile
    Cur = 0,
    /// the neighbor tile (x or y neighbor, direction depends on the plan)
    Nbr = 1,
}

/// A precomputed lane permutation: for each destination lane, the source
/// vector and source lane, plus whether the lane crosses the local-lattice
/// boundary when the neighbor tile wraps around.
#[derive(Clone, Debug)]
pub struct LanePlan {
    pub src: Vec<Src>,
    pub idx: Vec<usize>,
    /// lanes that read from the *wrapped* neighbor (candidates for
    /// boundary masking when the tile sits on the lattice edge)
    pub crosses: Vec<bool>,
}

impl LanePlan {
    /// Apply: `dst[l] = (src[l] == Cur ? cur : nbr)[idx[l]]`, the
    /// sel+tbl / ext analog. `mask_cross` zeroes boundary-crossing lanes.
    /// Generic over the lane scalar: the same plan serves f32 and f64
    /// field instantiations.
    #[inline]
    pub fn apply<R: Real>(&self, dst: &mut [R], cur: &[R], nbr: &[R], mask_cross: bool) {
        for l in 0..dst.len() {
            let v = match self.src[l] {
                Src::Cur => cur[self.idx[l]],
                Src::Nbr => nbr[self.idx[l]],
            };
            dst[l] = if mask_cross && self.crosses[l] { R::ZERO } else { v };
        }
    }

    /// Does any lane read from the neighbor tile?
    pub fn uses_neighbor(&self) -> bool {
        self.src.iter().any(|&s| s == Src::Nbr)
    }
}

/// All plans for one tiling: x+- for both row-parity phases, y+-.
///
/// `x_plus[b]` / `x_minus[b]` are indexed by the parity phase
/// `b = (yt*VLENY + z + t + p_out) mod 2` of the tile's first lane row;
/// rows within a tile alternate parity when `VLENY > 1`.
#[derive(Clone, Debug)]
pub struct ShiftPlans {
    pub tiling: Tiling,
    pub x_plus: [LanePlan; 2],
    pub x_minus: [LanePlan; 2],
    pub y_plus: LanePlan,
    pub y_minus: LanePlan,
}

impl ShiftPlans {
    pub fn new(tiling: Tiling) -> ShiftPlans {
        let (vx, vy) = (tiling.vx(), tiling.vy());
        let v = tiling.vlen();

        let build = |f: &dyn Fn(usize, usize) -> (Src, usize, usize, bool)| -> LanePlan {
            let mut plan = LanePlan {
                src: vec![Src::Cur; v],
                idx: vec![0; v],
                crosses: vec![false; v],
            };
            for ly in 0..vy {
                for lx in 0..vx {
                    let (src, slx, sly, cross) = f(lx, ly);
                    let dst = tiling.lane(lx, ly);
                    plan.src[dst] = src;
                    plan.idx[dst] = tiling.lane(slx, sly);
                    plan.crosses[dst] = cross;
                }
            }
            plan
        };

        // x+ with phase b: rows with phi(ly) = (b + ly) % 2 == 1 shift by 1
        let x_plus = std::array::from_fn(|b| {
            build(&|lx, ly| {
                if (b + ly) % 2 == 1 {
                    if lx + 1 < vx {
                        (Src::Cur, lx + 1, ly, false)
                    } else {
                        // crosses into the +x neighbor tile
                        (Src::Nbr, 0, ly, true)
                    }
                } else {
                    (Src::Cur, lx, ly, false)
                }
            })
        });
        // x- with phase b: rows with phi(ly) == 0 shift by -1
        let x_minus = std::array::from_fn(|b| {
            build(&|lx, ly| {
                if (b + ly) % 2 == 0 {
                    if lx > 0 {
                        (Src::Cur, lx - 1, ly, false)
                    } else {
                        (Src::Nbr, vx - 1, ly, true)
                    }
                } else {
                    (Src::Cur, lx, ly, false)
                }
            })
        });
        // y+: all rows shift up by one; last row reads the +y neighbor tile
        let y_plus = build(&|lx, ly| {
            if ly + 1 < vy {
                (Src::Cur, lx, ly + 1, false)
            } else {
                (Src::Nbr, lx, 0, true)
            }
        });
        let y_minus = build(&|lx, ly| {
            if ly > 0 {
                (Src::Cur, lx, ly - 1, false)
            } else {
                (Src::Nbr, lx, vy - 1, true)
            }
        });

        ShiftPlans {
            tiling,
            x_plus,
            x_minus,
            y_plus,
            y_minus,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Brute-force oracle: where should the +x-shifted value of lane
    /// (lx, ly) come from, given the row-parity phase?
    #[test]
    fn x_plus_matches_row_parity_rule() {
        for (vx, vy) in [(4, 4), (8, 2), (2, 8), (16, 1)] {
            let tiling = Tiling::new(vx, vy).unwrap();
            let plans = ShiftPlans::new(tiling);
            for b in 0..2 {
                let plan = &plans.x_plus[b];
                for ly in 0..vy {
                    let phi = (b + ly) % 2;
                    for lx in 0..vx {
                        let dst = tiling.lane(lx, ly);
                        if phi == 0 {
                            assert_eq!(plan.src[dst], Src::Cur);
                            assert_eq!(plan.idx[dst], dst, "no shift when phi=0");
                        } else if lx + 1 < vx {
                            assert_eq!(plan.idx[dst], tiling.lane(lx + 1, ly));
                        } else {
                            assert_eq!(plan.src[dst], Src::Nbr);
                            assert!(plan.crosses[dst]);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn x_plans_are_phase_complementary() {
        // a row that shifts in phase 0 must not shift in phase 1
        let tiling = Tiling::new(4, 4).unwrap();
        let plans = ShiftPlans::new(tiling);
        for ly in 0..4 {
            let l = tiling.lane(1, ly);
            let shifted0 = plans.x_plus[0].idx[l] != l;
            let shifted1 = plans.x_plus[1].idx[l] != l;
            assert_ne!(shifted0, shifted1);
        }
    }

    #[test]
    fn apply_merges_and_masks() {
        let tiling = Tiling::new(2, 2).unwrap();
        let plans = ShiftPlans::new(tiling);
        // cur = [0,1,2,3], nbr = [10,11,12,13]
        let cur: Vec<f32> = (0..4).map(|i| i as f32).collect();
        let nbr: Vec<f32> = (10..14).map(|i| i as f32).collect();
        let mut dst = vec![0.0; 4];
        // phase 0: row ly=0 has phi=0 (no shift), ly=1 phi=1 (shift);
        // the crossing lane (lx=1, ly=1) reads the neighbor's (lx=0, ly=1)
        plans.x_plus[0].apply(&mut dst, &cur, &nbr, false);
        assert_eq!(dst, vec![0.0, 1.0, 3.0, 12.0]);
        plans.x_plus[0].apply(&mut dst, &cur, &nbr, true);
        assert_eq!(dst, vec![0.0, 1.0, 3.0, 0.0], "crossing lane masked");
    }

    #[test]
    fn y_shift_is_ext_like() {
        let tiling = Tiling::new(2, 2).unwrap();
        let plans = ShiftPlans::new(tiling);
        let cur: Vec<f32> = (0..4).map(|i| i as f32).collect();
        let nbr: Vec<f32> = (10..14).map(|i| i as f32).collect();
        let mut dst = vec![0.0; 4];
        // +y: out row0 = cur row1, out row1 = nbr row0
        plans.y_plus.apply(&mut dst, &cur, &nbr, false);
        assert_eq!(dst, vec![2.0, 3.0, 10.0, 11.0]);
        plans.y_minus.apply(&mut dst, &cur, &nbr, false);
        assert_eq!(dst, vec![12.0, 13.0, 0.0, 1.0]);
    }

    #[test]
    fn vy1_tiling_shifts_whole_vector_or_not() {
        // 16x1 tiling: a row is the whole vector; phase decides everything
        let tiling = Tiling::new(16, 1).unwrap();
        let plans = ShiftPlans::new(tiling);
        assert!(!plans.x_plus[0].uses_neighbor(), "phi=0: no shift at all");
        assert!(plans.x_plus[1].uses_neighbor());
        // y always crosses (vy = 1)
        assert!(plans.y_plus.crosses.iter().all(|&c| c));
    }
}
