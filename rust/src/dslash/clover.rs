//! Clover term: the site-local `D_ee` / `D_oo` blocks of the clover
//! fermion matrix (the operator QWS implements; paper §2). For the plain
//! Wilson matrix these blocks are the identity; the clover improvement
//! adds `- kappa c_sw/2 sigma_munu F_munu(x)`, site-local and block
//! diagonal — exactly the structure the paper describes for QWS's
//! `D_ee`/`D_oo`.
//!
//! Implementation notes:
//! * `F_munu` is the clover-leaf average of the four plaquettes around
//!   `x`, anti-hermitized: `F = (Q - Q^dag) / 8`.
//! * `sigma_munu = (i/2) [gamma_mu, gamma_nu]`.
//! * The per-site operator `A(x) = 1 - (kappa c_sw / 2) sigma.F` is a
//!   hermitian 12x12 matrix in (spin, color) space; we store it densely
//!   and invert it with Gaussian elimination (needed for `D_ee^{-1}` in
//!   the even-odd preconditioning, Eq. 4).
//!
//! This is the extension feature; it is validated by unit tests
//! (hermiticity, unit-gauge identity, gamma5-hermiticity of the full
//! clover matrix, inverse correctness) rather than wired into the
//! benchmark harness.

use crate::algebra::{Complex, Gamma, Real, Spinor, GAMMA};
use crate::field::{FermionField, GaugeField};
use crate::lattice::{Dir, EvenOdd, Geometry, Parity, SiteCoord};

/// sigma_munu = (i/2)[g_mu, g_nu] as explicit 4x4 matrices.
fn sigma(mu: usize, nu: usize) -> Gamma {
    let a = GAMMA[mu].matmul(&GAMMA[nu]);
    let b = GAMMA[nu].matmul(&GAMMA[mu]);
    // (i/2)(a - b)
    let mut out = [[Complex::ZERO; 4]; 4];
    for i in 0..4 {
        for j in 0..4 {
            out[i][j] = (a.0[i][j] - b.0[i][j]).mul_i().scale(0.5);
        }
    }
    Gamma(out)
}

/// 3x3 color matrix helpers on [[Complex;3];3] via Su3 (not nec. unitary).
type Mat3 = crate::algebra::Su3;

/// Clover-leaf field strength F_munu(x) (anti-hermitian 3x3).
fn field_strength<R: Real>(
    u: &GaugeField<R>,
    geom: &Geometry,
    coords: [usize; 4],
    mu: usize,
    nu: usize,
) -> Mat3 {
    let ext = [geom.local.x, geom.local.y, geom.local.z, geom.local.t];
    let link = |dir: usize, c: [usize; 4]| -> Mat3 {
        u.link_at(Dir::from_index(dir), c[0], c[1], c[2], c[3])
    };
    let step = |mut c: [usize; 4], dir: usize, sign: i64| -> [usize; 4] {
        let n = ext[dir] as i64;
        c[dir] = ((c[dir] as i64 + sign).rem_euclid(n)) as usize;
        c
    };

    // the four leaves around x in the (mu, nu) plane
    let x = coords;
    let xp_mu = step(x, mu, 1);
    let xp_nu = step(x, nu, 1);
    let xm_mu = step(x, mu, -1);
    let xm_nu = step(x, nu, -1);
    let xp_mu_m_nu = step(xp_mu, nu, -1);
    let xm_mu_p_nu = step(xm_mu, nu, 1);
    let xm_mu_m_nu = step(xm_mu, nu, -1);

    // leaf 1: U_mu(x) U_nu(x+mu) U_mu(x+nu)^+ U_nu(x)^+
    let l1 = link(mu, x)
        .mul(&link(nu, xp_mu))
        .mul(&link(mu, xp_nu).adj())
        .mul(&link(nu, x).adj());
    // leaf 2: U_nu(x) U_mu(x-mu+nu)^+ U_nu(x-mu)^+ U_mu(x-mu)
    let l2 = link(nu, x)
        .mul(&link(mu, xm_mu_p_nu).adj())
        .mul(&link(nu, xm_mu).adj())
        .mul(&link(mu, xm_mu));
    // leaf 3: U_mu(x-mu)^+ U_nu(x-mu-nu)^+ U_mu(x-mu-nu) U_nu(x-nu)
    let l3 = link(mu, xm_mu)
        .adj()
        .mul(&link(nu, xm_mu_m_nu).adj())
        .mul(&link(mu, xm_mu_m_nu))
        .mul(&link(nu, xm_nu));
    // leaf 4: U_nu(x-nu)^+ U_mu(x-nu) U_nu(x+mu-nu) U_mu(x)^+
    let l4 = link(nu, xm_nu)
        .adj()
        .mul(&link(mu, xm_nu))
        .mul(&link(nu, xp_mu_m_nu))
        .mul(&link(mu, x).adj());

    // Q = sum of leaves; F = -i (Q - Q^dag)/8  (hermitian convention, so
    // sigma (x) F — and with it the whole clover block — is hermitian)
    let mut q = Mat3::default();
    for leaf in [l1, l2, l3, l4] {
        for a in 0..3 {
            for b in 0..3 {
                q.m[a][b] += leaf.m[a][b];
            }
        }
    }
    let qd = q.adj();
    let mut f = Mat3::default();
    for a in 0..3 {
        for b in 0..3 {
            f.m[a][b] = (q.m[a][b] - qd.m[a][b]).scale(1.0 / 8.0).mul_mi();
        }
    }
    f
}

/// The site-local clover operator of one parity: a dense hermitian 12x12
/// matrix per site, `A(x) = 1 - (kappa c_sw / 2) sum_{mu<nu} sigma.F`.
#[derive(Clone, Debug)]
pub struct CloverTerm {
    pub parity: Parity,
    /// per compacted site, row-major 12x12 (spin-major: i = 3*spin+color)
    pub blocks: Vec<[[Complex; 12]; 12]>,
    sites: Vec<SiteCoord>,
}

impl CloverTerm {
    /// Build the clover blocks from a gauge field of any precision; the
    /// leaf algebra itself always runs in f64.
    pub fn new<R: Real>(
        geom: &Geometry,
        u: &GaugeField<R>,
        parity: Parity,
        kappa: f64,
        c_sw: f64,
    ) -> CloverTerm {
        let layout = crate::lattice::EoLayout::new(geom);
        let sites: Vec<SiteCoord> = layout.sites().collect();
        let mut blocks = Vec::with_capacity(sites.len());
        // precompute sigma matrices for the 6 planes
        let planes: Vec<(usize, usize, Gamma)> = (0..4)
            .flat_map(|mu| ((mu + 1)..4).map(move |nu| (mu, nu)))
            .map(|(mu, nu)| (mu, nu, sigma(mu, nu)))
            .collect();
        for &s in &sites {
            let phi = EvenOdd::row_parity(s.y, s.z, s.t, parity);
            let coords = [EvenOdd::lexical_x(s.ix, phi), s.y, s.z, s.t];
            let mut block = [[Complex::ZERO; 12]; 12];
            for i in 0..12 {
                block[i][i] = Complex::ONE;
            }
            let coef = -kappa * c_sw * 0.5;
            for (mu, nu, sig) in &planes {
                let f = field_strength(u, geom, coords, *mu, *nu);
                // block -= (kappa c_sw / 2) * sigma (x) F   [factor 2 for
                // the mu<nu restriction: sigma_numu F_numu = sigma_munu F_munu]
                for si in 0..4 {
                    for sj in 0..4 {
                        let g = sig.0[si][sj];
                        if g == Complex::ZERO {
                            continue;
                        }
                        for ca in 0..3 {
                            for cb in 0..3 {
                                block[3 * si + ca][3 * sj + cb] +=
                                    (g * f.m[ca][cb]).scale(2.0 * coef);
                            }
                        }
                    }
                }
            }
            blocks.push(block);
        }
        CloverTerm {
            parity,
            blocks,
            sites,
        }
    }

    /// out = A psi (site-local block multiply), at the field's precision.
    pub fn apply<R: Real>(&self, out: &mut FermionField<R>, psi: &FermionField<R>) {
        for (k, &s) in self.sites.iter().enumerate() {
            let v = psi.site(s);
            let mut w = Spinor::ZERO;
            let block = &self.blocks[k];
            for i in 0..12 {
                let mut acc = Complex::ZERO;
                for j in 0..12 {
                    acc = acc.madd(block[i][j], v.s[j / 3][j % 3]);
                }
                w.s[i / 3][i % 3] = acc;
            }
            out.set_site(s, &w);
        }
    }

    /// Invert every site block (Gauss-Jordan with partial pivoting) —
    /// gives `D_ee^{-1}` / `D_oo^{-1}` for the preconditioning (Eq. 4).
    pub fn inverse(&self) -> CloverTerm {
        let blocks = self
            .blocks
            .iter()
            .map(|b| invert12(b).expect("clover block is singular"))
            .collect();
        CloverTerm {
            parity: self.parity,
            blocks,
            sites: self.sites.clone(),
        }
    }

    /// Hermiticity error max_i,j |A_ij - conj(A_ji)|.
    pub fn hermiticity_error(&self) -> f64 {
        let mut err = 0.0f64;
        for b in &self.blocks {
            for i in 0..12 {
                for j in 0..12 {
                    err = err.max((b[i][j] - b[j][i].conj()).abs());
                }
            }
        }
        err
    }
}

/// Dense 12x12 complex inverse (Gauss-Jordan, partial pivot).
fn invert12(a: &[[Complex; 12]; 12]) -> Option<[[Complex; 12]; 12]> {
    let mut m = *a;
    let mut inv = [[Complex::ZERO; 12]; 12];
    for i in 0..12 {
        inv[i][i] = Complex::ONE;
    }
    for col in 0..12 {
        // pivot
        let mut piv = col;
        for r in (col + 1)..12 {
            if m[r][col].norm2() > m[piv][col].norm2() {
                piv = r;
            }
        }
        if m[piv][col].norm2() < 1e-28 {
            return None;
        }
        m.swap(col, piv);
        inv.swap(col, piv);
        // normalize row
        let d = m[col][col];
        let dinv = d.conj().scale(1.0 / d.norm2());
        for j in 0..12 {
            m[col][j] = m[col][j] * dinv;
            inv[col][j] = inv[col][j] * dinv;
        }
        // eliminate
        for r in 0..12 {
            if r == col {
                continue;
            }
            let f = m[r][col];
            if f == Complex::ZERO {
                continue;
            }
            for j in 0..12 {
                m[r][j] = m[r][j] - f * m[col][j];
                inv[r][j] = inv[r][j] - f * inv[col][j];
            }
        }
    }
    Some(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::{LatticeDims, Tiling};
    use crate::util::rng::Rng;

    const KAPPA: f64 = 0.13;
    const CSW: f64 = 1.0;

    fn geom() -> Geometry {
        Geometry::single_rank(
            LatticeDims::new(4, 4, 4, 4).unwrap(),
            Tiling::new(2, 2).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn sigma_matrices_antisymmetric_and_hermitian() {
        for mu in 0..4 {
            for nu in (mu + 1)..4 {
                let s = sigma(mu, nu);
                let sn = sigma(nu, mu);
                for i in 0..4 {
                    for j in 0..4 {
                        assert!((s.0[i][j] + sn.0[i][j]).abs() < 1e-14);
                        assert!((s.0[i][j] - s.0[j][i].conj()).abs() < 1e-14);
                    }
                }
            }
        }
    }

    #[test]
    fn unit_gauge_clover_is_identity() {
        let g = geom();
        let u: GaugeField = GaugeField::unit(&g);
        let clov = CloverTerm::new(&g, &u, Parity::Even, KAPPA, CSW);
        let mut rng = Rng::seeded(61);
        let psi: FermionField = FermionField::gaussian(&g, &mut rng);
        let mut out = FermionField::zeros(&g);
        clov.apply(&mut out, &psi);
        let mut d = out.clone();
        d.axpy(-1.0, &psi);
        assert!(d.norm2() < 1e-10, "unit-gauge clover must be 1");
    }

    #[test]
    fn clover_block_is_hermitian() {
        let g = geom();
        let mut rng = Rng::seeded(62);
        let u: GaugeField = GaugeField::random(&g, &mut rng);
        let clov = CloverTerm::new(&g, &u, Parity::Odd, KAPPA, CSW);
        assert!(clov.hermiticity_error() < 1e-5, "{}", clov.hermiticity_error());
    }

    #[test]
    fn inverse_is_inverse() {
        let g = geom();
        let mut rng = Rng::seeded(63);
        let u: GaugeField = GaugeField::random(&g, &mut rng);
        let clov = CloverTerm::new(&g, &u, Parity::Even, KAPPA, CSW);
        let inv = clov.inverse();
        let psi: FermionField = FermionField::gaussian(&g, &mut rng);
        let mut mid = FermionField::zeros(&g);
        clov.apply(&mut mid, &psi);
        let mut back = FermionField::zeros(&g);
        inv.apply(&mut back, &mid);
        let mut d = back.clone();
        d.axpy(-1.0, &psi);
        let rel = (d.norm2() / psi.norm2()).sqrt();
        assert!(rel < 1e-5, "A^-1 A != 1: {rel}");
    }

    #[test]
    fn clover_gamma5_hermiticity() {
        // g5 A g5 = A^dag = A (hermitian) => A commutes appropriately:
        // verify <x, A y> == <A x, y>
        let g = geom();
        let mut rng = Rng::seeded(64);
        let u: GaugeField = GaugeField::random(&g, &mut rng);
        let clov = CloverTerm::new(&g, &u, Parity::Even, KAPPA, CSW);
        let x: FermionField = FermionField::gaussian(&g, &mut rng);
        let y: FermionField = FermionField::gaussian(&g, &mut rng);
        let mut ay = FermionField::zeros(&g);
        clov.apply(&mut ay, &y);
        let mut ax = FermionField::zeros(&g);
        clov.apply(&mut ax, &x);
        let lhs = x.dot(&ay);
        let rhs = ax.dot(&y);
        assert!((lhs.re - rhs.re).abs() < 1e-4 && (lhs.im - rhs.im).abs() < 1e-4);
    }

    #[test]
    fn field_strength_hermitian() {
        let g = geom();
        let mut rng = Rng::seeded(65);
        let u: GaugeField = GaugeField::random(&g, &mut rng);
        let f = field_strength(&u, &g, [1, 2, 3, 0], 0, 3);
        // hermitian convention: F - F^dag = 0
        let fd = f.adj();
        for a in 0..3 {
            for b in 0..3 {
                assert!((f.m[a][b] - fd.m[a][b]).abs() < 1e-10);
            }
        }
    }
}
