//! The gather/scatter variant of the even-odd hopping — Fig. 8 "before".
//!
//! The paper found that a leftover portable loop nest (outer loop over the
//! 24 (Re/Im)-spin-color components, inner over SIMD lanes) was compiled
//! into gather-load / scatter-store instructions, saturating the L1 cache
//! and bottlenecking the whole kernel. This module reproduces that code
//! shape deliberately:
//!
//! * neighbor access goes through *per-element index arithmetic* (a
//!   software gather: one `site_to_lane` address computation per lane per
//!   component) instead of the precomputed lane-shuffle plans;
//! * the accumulator is kept *lane-major* (`[V][24]`, i.e. AoS) and the
//!   final store walks components in the outer loop and lanes in the inner
//!   loop, producing the strided scatter pattern.
//!
//! `harness::fig8` profiles this against [`super::eo::HoppingEo`].

use crate::algebra::{Complex, Real, Spinor, PROJ};
use crate::field::{FermionField, GaugeField};
use crate::lattice::{
    Dir, EoLayout, EvenOdd, Geometry, Parity, SiteCoord, IM, RE, SC2,
};

/// Gather-style even-odd hopping operator (slow on purpose).
#[derive(Clone, Debug)]
pub struct HoppingGather {
    pub geom: Geometry,
    pub layout: EoLayout,
}

impl HoppingGather {
    pub fn new(geom: &Geometry) -> HoppingGather {
        HoppingGather {
            geom: *geom,
            layout: EoLayout::new(geom),
        }
    }

    /// out = H_{p_out <- 1-p_out} psi, periodic. Same result as the
    /// shuffle kernel, pathological access pattern.
    pub fn apply<R: Real>(
        &self,
        out: &mut FermionField<R>,
        u: &GaugeField<R>,
        psi: &FermionField<R>,
        p_out: Parity,
    ) {
        let ntiles = self.layout.ntiles();
        self.apply_tiles(&mut out.data, u, psi, p_out, 0, ntiles);
    }

    /// `out_tiles` covers exactly the output tiles `[tile_begin, tile_end)`.
    pub fn apply_tiles<R: Real>(
        &self,
        out_tiles: &mut [R],
        u: &GaugeField<R>,
        psi: &FermionField<R>,
        p_out: Parity,
        tile_begin: usize,
        tile_end: usize,
    ) {
        let l = &self.layout;
        let v = l.vlen();
        let d = self.geom.local;
        let ext = [d.x, d.y, d.z, d.t];
        let p_in = p_out.flip();

        // lane-major accumulator: [V][24] — the AoS shape whose final
        // store is a strided scatter
        let mut acc: Vec<Spinor> = vec![Spinor::ZERO; v];

        for tile in tile_begin..tile_end {
            acc.iter_mut().for_each(|a| *a = Spinor::ZERO);

            for lane in 0..v {
                // per-lane index arithmetic — the software gather
                let s = l.lane_to_site(crate::lattice::LaneCoord { tile, lane });
                let phi = EvenOdd::row_parity(s.y, s.z, s.t, p_out);
                let coords = [EvenOdd::lexical_x(s.ix, phi), s.y, s.z, s.t];
                for mu in 0..4 {
                    let mut cf = coords;
                    cf[mu] = (cf[mu] + 1) % ext[mu];
                    let nbr = SiteCoord {
                        t: cf[3],
                        z: cf[2],
                        y: cf[1],
                        ix: EvenOdd::compact_x(cf[0]),
                    };
                    let e = &PROJ[mu][0];
                    let h = e.project(&gather_site(psi, l, nbr));
                    let w = h.link_mul(&u.link(Dir::from_index(mu), p_out, s));
                    e.reconstruct_accum(&mut acc[lane], &w);

                    let mut cb = coords;
                    cb[mu] = (cb[mu] + ext[mu] - 1) % ext[mu];
                    let nbr = SiteCoord {
                        t: cb[3],
                        z: cb[2],
                        y: cb[1],
                        ix: EvenOdd::compact_x(cb[0]),
                    };
                    let e = &PROJ[mu][1];
                    let h = e.project(&gather_site(psi, l, nbr));
                    let w = h.link_adj_mul(&u.link(Dir::from_index(mu), p_in, nbr));
                    e.reconstruct_accum(&mut acc[lane], &w);
                }
            }

            // the pathological store: outer loop over the 24 components,
            // inner over lanes -> stride-V writes element by element
            let base = (tile - tile_begin) * SC2 * v;
            for spin in 0..4 {
                for color in 0..3 {
                    for reim in 0..2 {
                        let comp = ((spin * 3 + color) * 2 + reim) * v;
                        for lane in 0..v {
                            let val = if reim == RE {
                                acc[lane].s[spin][color].re
                            } else {
                                acc[lane].s[spin][color].im
                            };
                            out_tiles[base + comp + lane] = R::from_f64(val);
                        }
                    }
                }
            }
        }
    }
}

/// Element-by-element site load (the gather): each of the 24 components is
/// fetched through its own computed address.
fn gather_site<R: Real>(psi: &FermionField<R>, l: &EoLayout, s: SiteCoord) -> Spinor {
    let mut out = Spinor::ZERO;
    for spin in 0..4 {
        for color in 0..3 {
            out.s[spin][color] = Complex::new(
                psi.data[l.spinor_elem(s, spin, color, RE)].to_f64(),
                psi.data[l.spinor_elem(s, spin, color, IM)].to_f64(),
            );
        }
    }
    out
}
