//! Multi-RHS even-odd hopping: one gauge stream, N spinors.
//!
//! The single-RHS kernel ([`super::eo`]) is memory-bandwidth bound, and
//! most of what it streams is the gauge field: per output site a hopping
//! pass reads 8 links (144 values at f32) against one spinor in and one
//! out (48 values). Batching N right-hand sides against one gauge load
//! multiplies the kernel's arithmetic intensity by ~N on the link part —
//! the block-field layout of [`crate::field::block`] interleaves the N
//! spinors *inside* each site tile precisely so the per-(site, hop) link
//! tile stays in registers/L1 while it is applied to all N sub-tiles
//! back to back.
//!
//! The per-RHS arithmetic is byte-for-byte the single kernel's: the hop
//! order per site tile, the projection/SU(3)/reconstruction helpers, the
//! fused store tails and the dot capture are all shared with
//! [`super::eo`], so applying the multi kernel to a block field is
//! **bitwise identical** (at any precision) to applying [`HoppingEo`] to
//! each demuxed RHS separately.
//!
//! RHS whose `active` flag is false are skipped entirely — no shuffle,
//! no hops, no store, no capture — which is how the block solver's
//! per-RHS convergence masking stops converged systems from costing
//! kernel work.

use crate::algebra::Real;
use crate::field::blas;
use crate::lattice::{Parity, CC2, SC2};

use super::eo::{hop_bwd, hop_fwd, shuffle, tile_slice, HoppingEo, WrapMode};
use super::links::LinkSource;

/// Fused store tail of the multi-RHS kernel: the same expressions as
/// [`super::eo::StoreTail`], with `b` a *block-field* data slice
/// (indexed by sub-tile `site_tile * nrhs + rhs`, like the output).
#[derive(Clone, Copy)]
pub enum MultiStoreTail<'a, R: Real> {
    /// out = acc
    Assign,
    /// out = a * acc + b (per RHS)
    Xpay { a: R, b: &'a [R] },
    /// out = gamma5 * (a * acc + b) (per RHS)
    Gamma5Xpay { a: R, b: &'a [R] },
}

/// In-kernel per-(site tile, RHS) dot capture:
/// `partials[(tile - tile_begin) * nrhs + r] = [Re⟨with_r, out_r⟩,
/// Im⟨with_r, out_r⟩, |out_r|²]` in the canonical [`blas`] grouping.
/// Entries of masked RHS are left untouched.
pub struct MultiDotCapture<'a, R: Real> {
    /// block-field data slice, indexed by absolute sub-tile
    pub with: &'a [R],
    /// `(tile_end - tile_begin) * nrhs` entries
    pub partials: &'a mut [[f64; 3]],
}

impl HoppingEo {
    /// Multi-RHS analog of [`HoppingEo::apply_tiles_fused`]: apply the
    /// hopping to the *site*-tile range `[tile_begin, tile_end)` of a
    /// block field with `nrhs` interleaved right-hand sides.
    ///
    /// `out_tiles` covers `(tile_end - tile_begin) * nrhs` sub-tiles;
    /// `psi` (and the tail's `b` / capture's `with`) are full block-field
    /// data slices. Sub-tiles of RHS with `active[r] == false` are not
    /// read or written.
    #[allow(clippy::too_many_arguments)]
    pub fn apply_tiles_multi<R: Real, U: LinkSource<R>>(
        &self,
        out_tiles: &mut [R],
        u: &U,
        psi: &[R],
        p_out: Parity,
        tile_begin: usize,
        tile_end: usize,
        nrhs: usize,
        active: &[bool],
        tail: MultiStoreTail<R>,
        dot: Option<MultiDotCapture<R>>,
    ) {
        debug_assert_eq!(active.len(), nrhs);
        debug_assert_eq!(
            out_tiles.len(),
            (tile_end - tile_begin) * nrhs * SC2 * self.layout.vlen()
        );
        if !active.iter().any(|&a| a) {
            // nothing to feed: skip the link loads/reconstruction too
            return;
        }
        match self.layout.vlen() {
            2 => self.apply_multi_v::<R, U, 2>(out_tiles, u, psi, p_out, tile_begin, tile_end, nrhs, active, tail, dot),
            4 => self.apply_multi_v::<R, U, 4>(out_tiles, u, psi, p_out, tile_begin, tile_end, nrhs, active, tail, dot),
            8 => self.apply_multi_v::<R, U, 8>(out_tiles, u, psi, p_out, tile_begin, tile_end, nrhs, active, tail, dot),
            16 => self.apply_multi_v::<R, U, 16>(out_tiles, u, psi, p_out, tile_begin, tile_end, nrhs, active, tail, dot),
            32 => self.apply_multi_v::<R, U, 32>(out_tiles, u, psi, p_out, tile_begin, tile_end, nrhs, active, tail, dot),
            v => panic!("unsupported VLEN {v} (expected 2/4/8/16/32)"),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn apply_multi_v<R: Real, U: LinkSource<R>, const V: usize>(
        &self,
        out_tiles: &mut [R],
        u: &U,
        psi: &[R],
        p_out: Parity,
        tile_begin: usize,
        tile_end: usize,
        nrhs: usize,
        active: &[bool],
        tail: MultiStoreTail<R>,
        mut dot: Option<MultiDotCapture<R>>,
    ) {
        let l = &self.layout;
        debug_assert_eq!(l.vlen(), V);
        let p_in = p_out.flip();
        let (nxt, nyt, nz, nt) = (l.nxt, l.nyt, l.nz, l.nt);
        let vy = l.tiling.vy();

        // scratch: the shifted-spinor / half-spinor tiles are reused
        // sequentially per RHS; the accumulators are per-RHS so every
        // hop's link data is consumed by all N spinors while hot
        let mut ps = vec![R::ZERO; SC2 * V];
        let mut us = vec![R::ZERO; CC2 * V];
        // reconstruction buffer: a compressed source rebuilds each hop's
        // link tile here ONCE per site tile, and all N RHS consume it
        let mut uf = vec![R::ZERO; CC2 * V];
        let mut h = vec![R::ZERO; 12 * V];
        let mut acc = vec![R::ZERO; nrhs * SC2 * V];

        // sub-tile index of (site tile, rhs) into block-field storage
        let st = |tile: usize, r: usize| tile * nrhs + r;

        for tile in tile_begin..tile_end {
            let (t, z, yt, xt) = l.tile_coords(tile);
            let b = (yt * vy + z + t + p_out.index()) % 2;
            acc.iter_mut().for_each(|a| *a = R::ZERO);

            // ---------------- X direction ----------------
            {
                let skip = self.wrap[0] == WrapMode::SkipBoundary;
                let nbr = l.tile_index(t, z, yt, (xt + 1) % nxt);
                let mask = skip && xt + 1 == nxt;
                let plan = &self.plans.x_plus[b];
                let u_tile = u.link_tile::<V>(0, p_out, tile, &mut uf);
                for r in 0..nrhs {
                    if !active[r] {
                        continue;
                    }
                    shuffle::<R, V>(&mut ps, tile_slice::<R, V>(psi, st(tile, r), SC2), tile_slice::<R, V>(psi, st(nbr, r), SC2), plan, mask, SC2);
                    hop_fwd::<R, V>(&mut acc[r * SC2 * V..(r + 1) * SC2 * V], &mut h, &ps, u_tile, &crate::algebra::PROJ[0][0]);
                }

                let nbr = l.tile_index(t, z, yt, (xt + nxt - 1) % nxt);
                let mask = skip && xt == 0;
                let plan = &self.plans.x_minus[b];
                // the backward link shuffle (and, for compressed links,
                // the reconstruction) is RHS-independent: once per hop
                u.link_tile_shifted::<V>(0, p_in, tile, nbr, plan, &mut us);
                for r in 0..nrhs {
                    if !active[r] {
                        continue;
                    }
                    shuffle::<R, V>(&mut ps, tile_slice::<R, V>(psi, st(tile, r), SC2), tile_slice::<R, V>(psi, st(nbr, r), SC2), plan, mask, SC2);
                    hop_bwd::<R, V>(&mut acc[r * SC2 * V..(r + 1) * SC2 * V], &mut h, &ps, &us, &crate::algebra::PROJ[0][1]);
                }
            }

            // ---------------- Y direction ----------------
            {
                let skip = self.wrap[1] == WrapMode::SkipBoundary;
                let nbr = l.tile_index(t, z, (yt + 1) % nyt, xt);
                let mask = skip && yt + 1 == nyt;
                let plan = &self.plans.y_plus;
                let u_tile = u.link_tile::<V>(1, p_out, tile, &mut uf);
                for r in 0..nrhs {
                    if !active[r] {
                        continue;
                    }
                    shuffle::<R, V>(&mut ps, tile_slice::<R, V>(psi, st(tile, r), SC2), tile_slice::<R, V>(psi, st(nbr, r), SC2), plan, mask, SC2);
                    hop_fwd::<R, V>(&mut acc[r * SC2 * V..(r + 1) * SC2 * V], &mut h, &ps, u_tile, &crate::algebra::PROJ[1][0]);
                }

                let nbr = l.tile_index(t, z, (yt + nyt - 1) % nyt, xt);
                let mask = skip && yt == 0;
                let plan = &self.plans.y_minus;
                u.link_tile_shifted::<V>(1, p_in, tile, nbr, plan, &mut us);
                for r in 0..nrhs {
                    if !active[r] {
                        continue;
                    }
                    shuffle::<R, V>(&mut ps, tile_slice::<R, V>(psi, st(tile, r), SC2), tile_slice::<R, V>(psi, st(nbr, r), SC2), plan, mask, SC2);
                    hop_bwd::<R, V>(&mut acc[r * SC2 * V..(r + 1) * SC2 * V], &mut h, &ps, &us, &crate::algebra::PROJ[1][1]);
                }
            }

            // ---------------- Z direction (whole-tile strides) ----------
            {
                let skip = self.wrap[2] == WrapMode::SkipBoundary;
                if !(skip && z + 1 == nz) {
                    let nbr = l.tile_index(t, (z + 1) % nz, yt, xt);
                    let u_tile = u.link_tile::<V>(2, p_out, tile, &mut uf);
                    for r in 0..nrhs {
                        if !active[r] {
                            continue;
                        }
                        hop_fwd::<R, V>(&mut acc[r * SC2 * V..(r + 1) * SC2 * V], &mut h, tile_slice::<R, V>(psi, st(nbr, r), SC2), u_tile, &crate::algebra::PROJ[2][0]);
                    }
                }
                if !(skip && z == 0) {
                    let nbr = l.tile_index(t, (z + nz - 1) % nz, yt, xt);
                    let u_tile = u.link_tile::<V>(2, p_in, nbr, &mut uf);
                    for r in 0..nrhs {
                        if !active[r] {
                            continue;
                        }
                        hop_bwd::<R, V>(&mut acc[r * SC2 * V..(r + 1) * SC2 * V], &mut h, tile_slice::<R, V>(psi, st(nbr, r), SC2), u_tile, &crate::algebra::PROJ[2][1]);
                    }
                }
            }

            // ---------------- T direction (whole-tile strides) ----------
            {
                let skip = self.wrap[3] == WrapMode::SkipBoundary;
                if !(skip && t + 1 == nt) {
                    let nbr = l.tile_index((t + 1) % nt, z, yt, xt);
                    let u_tile = u.link_tile::<V>(3, p_out, tile, &mut uf);
                    for r in 0..nrhs {
                        if !active[r] {
                            continue;
                        }
                        hop_fwd::<R, V>(&mut acc[r * SC2 * V..(r + 1) * SC2 * V], &mut h, tile_slice::<R, V>(psi, st(nbr, r), SC2), u_tile, &crate::algebra::PROJ[3][0]);
                    }
                }
                if !(skip && t == 0) {
                    let nbr = l.tile_index((t + nt - 1) % nt, z, yt, xt);
                    let u_tile = u.link_tile::<V>(3, p_in, nbr, &mut uf);
                    for r in 0..nrhs {
                        if !active[r] {
                            continue;
                        }
                        hop_bwd::<R, V>(&mut acc[r * SC2 * V..(r + 1) * SC2 * V], &mut h, tile_slice::<R, V>(psi, st(nbr, r), SC2), u_tile, &crate::algebra::PROJ[3][1]);
                    }
                }
            }

            // store per RHS, applying the fused tail (same expressions as
            // the single kernel, so per-RHS results bit-match it)
            let rel = tile - tile_begin;
            for r in 0..nrhs {
                if !active[r] {
                    continue;
                }
                let ar = &acc[r * SC2 * V..(r + 1) * SC2 * V];
                let dst = &mut out_tiles
                    [(rel * nrhs + r) * SC2 * V..(rel * nrhs + r + 1) * SC2 * V];
                match tail {
                    MultiStoreTail::Assign => dst.copy_from_slice(ar),
                    MultiStoreTail::Xpay { a, b } => {
                        let bt = tile_slice::<R, V>(b, st(tile, r), SC2);
                        for i in 0..SC2 * V {
                            dst[i] = a * ar[i] + bt[i];
                        }
                    }
                    MultiStoreTail::Gamma5Xpay { a, b } => {
                        let bt = tile_slice::<R, V>(b, st(tile, r), SC2);
                        for c in 0..SC2 {
                            let lower = c / 6 >= 2;
                            for i in c * V..(c + 1) * V {
                                let v = a * ar[i] + bt[i];
                                dst[i] = if lower { -v } else { v };
                            }
                        }
                    }
                }
                if let Some(cap) = dot.as_mut() {
                    let wt = tile_slice::<R, V>(cap.with, st(tile, r), SC2);
                    cap.partials[rel * nrhs + r] = blas::cdot_norm2_tile(wt, dst, V);
                }
            }
        }
    }
}
