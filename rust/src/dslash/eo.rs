//! The vectorized even-odd Wilson hopping kernel — the paper's kernel
//! (§3.3-3.4), and the Rust analog of its ACLE implementation.
//!
//! `H_{p_out <- p_in}` is applied tile by tile. Per output tile and
//! direction the kernel
//!
//! 1. builds the shifted source spinor (and, for backward hops, the
//!    shifted link) with the lane-shuffle engine ([`super::shift`]) —
//!    never with gather/scatter (that variant lives in [`super::gather`]
//!    and is what Fig. 8 "before" profiles);
//! 2. projects 4 -> 2 spin components with the `(1 -+ gamma_mu)` tables;
//! 3. multiplies the 3x3 link into the half-spinor on the lanes;
//! 4. reconstructs and accumulates the 4-spinor.
//!
//! All lane loops run over a compile-time `V = VLEN` so the compiler
//! vectorizes them; `apply` dispatches on the runtime tiling. The whole
//! kernel is generic over the [`Real`] lane scalar — the f32
//! instantiation is the paper's benchmark kernel, the f64 one backs the
//! oracle comparisons and the mixed-precision outer operator.

use crate::algebra::{Coef, ProjEntry, Real, PROJ};
use crate::field::{blas, FermionField};
use crate::lattice::{EoLayout, Geometry, Parity, CC2, SC2};

use super::links::LinkSource;
use super::shift::{LanePlan, ShiftPlans};

/// How the kernel's accumulated tile is stored to the output: the tail
/// of the even-odd operator fused into the store instead of running as
/// a separate full-field pass afterwards.
///
/// `b` is the full-field data slice of the same layout as the output
/// (indexed by absolute tile). The fused expressions evaluate exactly
/// like their two-pass references — `Xpay` matches `apply` followed by
/// `FermionField::xpay`, `Gamma5Xpay` additionally matches a trailing
/// `gamma5` — so fused results are bit-identical at any precision.
#[derive(Clone, Copy)]
pub enum StoreTail<'a, R: Real> {
    /// out = acc (the plain hopping store)
    Assign,
    /// out = a * acc + b (the M-hat `-kappa²` + identity tail)
    Xpay { a: R, b: &'a [R] },
    /// out = gamma5 * (a * acc + b) (the normal operator's tail)
    Gamma5Xpay { a: R, b: &'a [R] },
}

/// In-kernel dot capture: for each output tile the kernel writes
/// `partials[tile - tile_begin] = [Re⟨with, out⟩, Im⟨with, out⟩, |out|²]`
/// (`with` conjugated, canonical [`blas`] grouping) right after the
/// store, while the freshly written tile is still in registers/L1 —
/// the solver's `p·Ap`-style reduction costs no extra field sweep.
pub struct DotCapture<'a, R: Real> {
    /// full-field data slice, indexed by absolute tile
    pub with: &'a [R],
    /// one entry per tile of the applied range
    pub partials: &'a mut [[f64; 3]],
}

/// How to treat the local-lattice boundary in each direction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WrapMode {
    /// Periodic wrap inside the local lattice (single-rank operator).
    Periodic,
    /// Skip contributions crossing the boundary; they are supplied by the
    /// halo-exchange path (EO1/EO2).
    SkipBoundary,
}

/// The vectorized even-odd hopping operator.
///
/// The struct itself holds only the layout and lane plans — precision
/// enters through the generic `apply` / `apply_tiles` methods, so one
/// operator instance serves both f32 and f64 fields.
#[derive(Clone, Debug)]
pub struct HoppingEo {
    pub layout: EoLayout,
    pub plans: ShiftPlans,
    pub wrap: [WrapMode; 4],
}

impl HoppingEo {
    /// Fully periodic operator (single-rank use).
    pub fn new(geom: &Geometry) -> HoppingEo {
        HoppingEo {
            layout: EoLayout::new(geom),
            plans: ShiftPlans::new(geom.tiling),
            wrap: [WrapMode::Periodic; 4],
        }
    }

    /// Operator with per-direction boundary handling (multi-rank bulk part).
    pub fn with_wrap(geom: &Geometry, wrap: [WrapMode; 4]) -> HoppingEo {
        HoppingEo {
            layout: EoLayout::new(geom),
            plans: ShiftPlans::new(geom.tiling),
            wrap,
        }
    }

    /// out = H_{p_out <- p_in} psi. `psi` has parity `1 - p_out`.
    /// Generic over the [`LinkSource`]: a full [`crate::field::GaugeField`]
    /// streams its tiles copy-free, a compressed source rebuilds the
    /// third row in-tile.
    pub fn apply<R: Real, U: LinkSource<R>>(
        &self,
        out: &mut FermionField<R>,
        u: &U,
        psi: &FermionField<R>,
        p_out: Parity,
    ) {
        let ntiles = self.layout.ntiles();
        self.apply_tiles(&mut out.data, u, psi, p_out, 0, ntiles);
    }

    /// Apply to a contiguous range of output tiles (the unit the thread
    /// team distributes). `out_tiles` covers exactly the tiles
    /// `[tile_begin, tile_end)` of the output field.
    pub fn apply_tiles<R: Real, U: LinkSource<R>>(
        &self,
        out_tiles: &mut [R],
        u: &U,
        psi: &FermionField<R>,
        p_out: Parity,
        tile_begin: usize,
        tile_end: usize,
    ) {
        self.apply_tiles_fused(
            out_tiles,
            u,
            &psi.data,
            p_out,
            tile_begin,
            tile_end,
            StoreTail::Assign,
            None,
        );
    }

    /// [`Self::apply_tiles`] with a fused store tail and optional
    /// in-kernel dot capture. `psi` is the source field's data slice
    /// (so team phases can feed scratch written through raw pointers).
    #[allow(clippy::too_many_arguments)]
    pub fn apply_tiles_fused<R: Real, U: LinkSource<R>>(
        &self,
        out_tiles: &mut [R],
        u: &U,
        psi: &[R],
        p_out: Parity,
        tile_begin: usize,
        tile_end: usize,
        tail: StoreTail<R>,
        dot: Option<DotCapture<R>>,
    ) {
        debug_assert_eq!(
            out_tiles.len(),
            (tile_end - tile_begin) * SC2 * self.layout.vlen()
        );
        match self.layout.vlen() {
            2 => self.apply_v::<R, U, 2>(out_tiles, u, psi, p_out, tile_begin, tile_end, tail, dot),
            4 => self.apply_v::<R, U, 4>(out_tiles, u, psi, p_out, tile_begin, tile_end, tail, dot),
            8 => self.apply_v::<R, U, 8>(out_tiles, u, psi, p_out, tile_begin, tile_end, tail, dot),
            16 => self.apply_v::<R, U, 16>(out_tiles, u, psi, p_out, tile_begin, tile_end, tail, dot),
            32 => self.apply_v::<R, U, 32>(out_tiles, u, psi, p_out, tile_begin, tile_end, tail, dot),
            v => panic!("unsupported VLEN {v} (expected 2/4/8/16/32)"),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn apply_v<R: Real, U: LinkSource<R>, const V: usize>(
        &self,
        out_tiles: &mut [R],
        u: &U,
        psi: &[R],
        p_out: Parity,
        tile_begin: usize,
        tile_end: usize,
        tail: StoreTail<R>,
        mut dot: Option<DotCapture<R>>,
    ) {
        let l = &self.layout;
        debug_assert_eq!(l.vlen(), V);
        let p_in = p_out.flip();
        let (nxt, nyt, nz, nt) = (l.nxt, l.nyt, l.nz, l.nt);
        let vy = l.tiling.vy();

        // scratch tiles (per-call; the thread team gives each thread its own)
        let mut ps = vec![R::ZERO; SC2 * V]; // shifted spinor tile
        let mut us = vec![R::ZERO; CC2 * V]; // shifted link tile
        let mut uf = vec![R::ZERO; CC2 * V]; // reconstruction buffer (compressed sources)
        let mut h = vec![R::ZERO; 12 * V]; // projected half spinor
        let mut acc = vec![R::ZERO; SC2 * V];

        for tile in tile_begin..tile_end {
            let (t, z, yt, xt) = l.tile_coords(tile);
            // row-parity phase of the tile's first lane row (Fig. 5)
            let b = (yt * vy + z + t + p_out.index()) % 2;
            acc.iter_mut().for_each(|a| *a = R::ZERO);

            // ---------------- X direction ----------------
            {
                let skip = self.wrap[0] == WrapMode::SkipBoundary;
                // forward: neighbor tile at xt+1 (wraps at the edge)
                let nbr = l.tile_index(t, z, yt, (xt + 1) % nxt);
                let mask = skip && xt + 1 == nxt;
                let plan = &self.plans.x_plus[b];
                shuffle::<R, V>(&mut ps, tile_slice::<R, V>(psi, tile, SC2), tile_slice::<R, V>(psi, nbr, SC2), plan, mask, SC2);
                hop_fwd::<R, V>(&mut acc, &mut h, &ps, u.link_tile::<V>(0, p_out, tile, &mut uf), &PROJ[0][0]);

                // backward: neighbor tile at xt-1; link U_x(x - x^) shifts too
                let nbr = l.tile_index(t, z, yt, (xt + nxt - 1) % nxt);
                let mask = skip && xt == 0;
                let plan = &self.plans.x_minus[b];
                shuffle::<R, V>(&mut ps, tile_slice::<R, V>(psi, tile, SC2), tile_slice::<R, V>(psi, nbr, SC2), plan, mask, SC2);
                u.link_tile_shifted::<V>(0, p_in, tile, nbr, plan, &mut us);
                hop_bwd::<R, V>(&mut acc, &mut h, &ps, &us, &PROJ[0][1]);
            }

            // ---------------- Y direction ----------------
            {
                let skip = self.wrap[1] == WrapMode::SkipBoundary;
                let nbr = l.tile_index(t, z, (yt + 1) % nyt, xt);
                let mask = skip && yt + 1 == nyt;
                let plan = &self.plans.y_plus;
                shuffle::<R, V>(&mut ps, tile_slice::<R, V>(psi, tile, SC2), tile_slice::<R, V>(psi, nbr, SC2), plan, mask, SC2);
                hop_fwd::<R, V>(&mut acc, &mut h, &ps, u.link_tile::<V>(1, p_out, tile, &mut uf), &PROJ[1][0]);

                let nbr = l.tile_index(t, z, (yt + nyt - 1) % nyt, xt);
                let mask = skip && yt == 0;
                let plan = &self.plans.y_minus;
                shuffle::<R, V>(&mut ps, tile_slice::<R, V>(psi, tile, SC2), tile_slice::<R, V>(psi, nbr, SC2), plan, mask, SC2);
                u.link_tile_shifted::<V>(1, p_in, tile, nbr, plan, &mut us);
                hop_bwd::<R, V>(&mut acc, &mut h, &ps, &us, &PROJ[1][1]);
            }

            // ---------------- Z direction (whole-tile strides) ----------
            {
                let skip = self.wrap[2] == WrapMode::SkipBoundary;
                if !(skip && z + 1 == nz) {
                    let nbr = l.tile_index(t, (z + 1) % nz, yt, xt);
                    hop_fwd::<R, V>(&mut acc, &mut h, tile_slice::<R, V>(psi, nbr, SC2), u.link_tile::<V>(2, p_out, tile, &mut uf), &PROJ[2][0]);
                }
                if !(skip && z == 0) {
                    let nbr = l.tile_index(t, (z + nz - 1) % nz, yt, xt);
                    hop_bwd::<R, V>(&mut acc, &mut h, tile_slice::<R, V>(psi, nbr, SC2), u.link_tile::<V>(2, p_in, nbr, &mut uf), &PROJ[2][1]);
                }
            }

            // ---------------- T direction (whole-tile strides) ----------
            {
                let skip = self.wrap[3] == WrapMode::SkipBoundary;
                if !(skip && t + 1 == nt) {
                    let nbr = l.tile_index((t + 1) % nt, z, yt, xt);
                    hop_fwd::<R, V>(&mut acc, &mut h, tile_slice::<R, V>(psi, nbr, SC2), u.link_tile::<V>(3, p_out, tile, &mut uf), &PROJ[3][0]);
                }
                if !(skip && t == 0) {
                    let nbr = l.tile_index((t + nt - 1) % nt, z, yt, xt);
                    hop_bwd::<R, V>(&mut acc, &mut h, tile_slice::<R, V>(psi, nbr, SC2), u.link_tile::<V>(3, p_in, nbr, &mut uf), &PROJ[3][1]);
                }
            }

            // store the accumulated tile, applying the fused tail
            let rel = tile - tile_begin;
            let dst = &mut out_tiles[rel * SC2 * V..(rel + 1) * SC2 * V];
            match tail {
                StoreTail::Assign => dst.copy_from_slice(&acc),
                StoreTail::Xpay { a, b } => {
                    let bt = tile_slice::<R, V>(b, tile, SC2);
                    for i in 0..SC2 * V {
                        dst[i] = a * acc[i] + bt[i];
                    }
                }
                StoreTail::Gamma5Xpay { a, b } => {
                    let bt = tile_slice::<R, V>(b, tile, SC2);
                    for c in 0..SC2 {
                        // component c belongs to spin c / 6; gamma5
                        // negates spins 2 and 3 (exact, so fusing it
                        // here bit-matches a trailing gamma5 pass)
                        let lower = c / 6 >= 2;
                        for i in c * V..(c + 1) * V {
                            let v = a * acc[i] + bt[i];
                            dst[i] = if lower { -v } else { v };
                        }
                    }
                }
            }
            if let Some(cap) = dot.as_mut() {
                let wt = tile_slice::<R, V>(cap.with, tile, SC2);
                cap.partials[rel] = blas::cdot_norm2_tile(wt, dst, V);
            }
        }
    }
}

/// The SC2*V (or CC2*V) block of one tile. (`pub(super)`: shared with
/// the multi-RHS kernel in [`super::multi`], which indexes spinor data
/// by *sub-tile* — `site_tile * nrhs + rhs` — through the same helper.)
#[inline]
pub(super) fn tile_slice<R: Real, const V: usize>(data: &[R], tile: usize, ncomp: usize) -> &[R] {
    &data[tile * ncomp * V..(tile + 1) * ncomp * V]
}

/// Apply a lane plan to every component vector of a tile block.
#[inline]
pub(super) fn shuffle<R: Real, const V: usize>(
    dst: &mut [R],
    cur: &[R],
    nbr: &[R],
    plan: &LanePlan,
    mask: bool,
    ncomp: usize,
) {
    for k in 0..ncomp {
        plan.apply(&mut dst[k * V..(k + 1) * V], &cur[k * V..(k + 1) * V], &nbr[k * V..(k + 1) * V], mask);
    }
}

/// Fixed-size view of the component vector at `off` (bounds-checked once;
/// the lane loops below then vectorize without per-element checks).
#[inline(always)]
fn arr<R: Real, const V: usize>(s: &[R], off: usize) -> &[R; V] {
    s[off..off + V].try_into().unwrap()
}

/// Mutable (re, im) pair of adjacent component vectors starting at `off`.
#[inline(always)]
fn arr_pair_mut<R: Real, const V: usize>(
    s: &mut [R],
    off: usize,
) -> (&mut [R; V], &mut [R; V]) {
    let (a, b) = s[off..off + 2 * V].split_at_mut(V);
    (a.try_into().unwrap(), b.try_into().unwrap())
}

/// dst = a + coef * b, lanewise on split re/im vectors.
#[inline]
fn add_coef<R: Real, const V: usize>(
    dst_re: &mut [R; V],
    dst_im: &mut [R; V],
    a_re: &[R; V],
    a_im: &[R; V],
    b_re: &[R; V],
    b_im: &[R; V],
    coef: Coef,
) {
    match coef {
        Coef::One => {
            for l in 0..V {
                dst_re[l] = a_re[l] + b_re[l];
                dst_im[l] = a_im[l] + b_im[l];
            }
        }
        Coef::MinusOne => {
            for l in 0..V {
                dst_re[l] = a_re[l] - b_re[l];
                dst_im[l] = a_im[l] - b_im[l];
            }
        }
        Coef::I => {
            for l in 0..V {
                dst_re[l] = a_re[l] - b_im[l];
                dst_im[l] = a_im[l] + b_re[l];
            }
        }
        Coef::MinusI => {
            for l in 0..V {
                dst_re[l] = a_re[l] + b_im[l];
                dst_im[l] = a_im[l] - b_re[l];
            }
        }
    }
}

/// Offsets into a spinor tile block: component (spin, color, reim) vector.
#[inline(always)]
const fn so<const V: usize>(s: usize, c: usize, reim: usize) -> usize {
    ((s * 3 + c) * 2 + reim) * V
}

/// Offsets into a gauge tile block: component (a, b, reim) vector.
#[inline(always)]
const fn go<const V: usize>(a: usize, b: usize, reim: usize) -> usize {
    ((a * 3 + b) * 2 + reim) * V
}

/// Project the 4-spinor tile `ps` to the half-spinor `h` (2 x 3 x 2 x V).
#[inline]
fn project<R: Real, const V: usize>(h: &mut [R], ps: &[R], e: &ProjEntry) {
    for c in 0..3 {
        // h0 = psi_0 + c1 * psi_j1
        let (dr, di) = arr_pair_mut::<R, V>(h, so::<V>(0, c, 0));
        add_coef::<R, V>(
            dr,
            di,
            arr::<R, V>(ps, so::<V>(0, c, 0)),
            arr::<R, V>(ps, so::<V>(0, c, 1)),
            arr::<R, V>(ps, so::<V>(e.j1, c, 0)),
            arr::<R, V>(ps, so::<V>(e.j1, c, 1)),
            e.c1,
        );
        // h1 = psi_1 + c2 * psi_j2
        let (dr, di) = arr_pair_mut::<R, V>(h, so::<V>(1, c, 0));
        add_coef::<R, V>(
            dr,
            di,
            arr::<R, V>(ps, so::<V>(1, c, 0)),
            arr::<R, V>(ps, so::<V>(1, c, 1)),
            arr::<R, V>(ps, so::<V>(e.j2, c, 0)),
            arr::<R, V>(ps, so::<V>(e.j2, c, 1)),
            e.c2,
        );
    }
}

#[inline]
fn accum_coef<R: Real, const V: usize>(
    acc: &mut [R],
    spin: usize,
    c: usize,
    wr: &[R; V],
    wi: &[R; V],
    coef: Coef,
) {
    let (dr, di) = arr_pair_mut::<R, V>(acc, so::<V>(spin, c, 0));
    match coef {
        Coef::One => {
            for l in 0..V {
                dr[l] += wr[l];
                di[l] += wi[l];
            }
        }
        Coef::MinusOne => {
            for l in 0..V {
                dr[l] -= wr[l];
                di[l] -= wi[l];
            }
        }
        Coef::I => {
            for l in 0..V {
                dr[l] -= wi[l];
                di[l] += wr[l];
            }
        }
        Coef::MinusI => {
            for l in 0..V {
                dr[l] += wi[l];
                di[l] -= wr[l];
            }
        }
    }
}

/// Fused SU(3) multiply + reconstruction: computes w[s][a] and
/// accumulates the reconstructed 4-spinor without materializing `w`
/// (saves one 12xV round trip per hop).
#[inline]
fn su3_mul_reconstruct<R: Real, const V: usize>(
    acc: &mut [R],
    u: &[R],
    h: &[R],
    dag: bool,
    e: &ProjEntry,
) {
    for s in 0..2 {
        for a in 0..3 {
            let mut wr = [R::ZERO; V];
            let mut wi = [R::ZERO; V];
            for b in 0..3 {
                let (ur, ui): (&[R; V], &[R; V]) = if dag {
                    (arr::<R, V>(u, go::<V>(b, a, 0)), arr::<R, V>(u, go::<V>(b, a, 1)))
                } else {
                    (arr::<R, V>(u, go::<V>(a, b, 0)), arr::<R, V>(u, go::<V>(a, b, 1)))
                };
                let hr = arr::<R, V>(h, so::<V>(s, b, 0));
                let hi = arr::<R, V>(h, so::<V>(s, b, 1));
                if dag {
                    for l in 0..V {
                        wr[l] += ur[l] * hr[l] + ui[l] * hi[l];
                        wi[l] += ur[l] * hi[l] - ui[l] * hr[l];
                    }
                } else {
                    for l in 0..V {
                        wr[l] += ur[l] * hr[l] - ui[l] * hi[l];
                        wi[l] += ur[l] * hi[l] + ui[l] * hr[l];
                    }
                }
            }
            // upper rows: acc[s] += w
            {
                let (dr, di) = arr_pair_mut::<R, V>(acc, so::<V>(s, a, 0));
                for l in 0..V {
                    dr[l] += wr[l];
                    di[l] += wi[l];
                }
            }
            // lower rows fed by this w row
            if e.k1 == s {
                accum_coef::<R, V>(acc, 2, a, &wr, &wi, e.d1);
            }
            if e.k2 == s {
                accum_coef::<R, V>(acc, 3, a, &wr, &wi, e.d2);
            }
        }
    }
}

/// Forward hop on one tile: project, multiply U, reconstruct-accumulate.
#[inline]
pub(super) fn hop_fwd<R: Real, const V: usize>(
    acc: &mut [R],
    h: &mut [R],
    ps: &[R],
    u_tile: &[R],
    e: &ProjEntry,
) {
    project::<R, V>(h, ps, e);
    su3_mul_reconstruct::<R, V>(acc, u_tile, h, false, e);
}

/// Backward hop on one tile: project, multiply U^dag, reconstruct.
#[inline]
pub(super) fn hop_bwd<R: Real, const V: usize>(
    acc: &mut [R],
    h: &mut [R],
    ps: &[R],
    u_tile: &[R],
    e: &ProjEntry,
) {
    project::<R, V>(h, ps, e);
    su3_mul_reconstruct::<R, V>(acc, u_tile, h, true, e);
}
