//! Link sources: how gauge links flow into the stencil kernels.
//!
//! The hopping kernels ([`super::eo`], [`super::multi`]) and the
//! distributed driver's halo helpers are generic over [`LinkSource`]: a
//! provider of per-(direction, parity, tile) link tiles in the full
//! `CC2 * VLEN` layout the SU(3) lane math consumes. Two providers
//! exist:
//!
//! * [`GaugeField`] — *copy-through*: `link_tile` borrows the tile
//!   straight out of storage (zero copies, the pre-compression hot
//!   path, bit-for-bit the old kernel);
//! * [`CompressedGaugeField`] — *in-tile two-row reconstruction*: the 12
//!   stored component vectors are copied (or, for the backward hop,
//!   lane-shuffled via the [`super::shift`] plan) into the caller's tile
//!   buffer and the 6 third-row vectors are rebuilt lanewise
//!   ([`crate::field::compressed::reconstruct_third_row`]). Because the
//!   shuffle is a pure lane permutation and the rebuild is lanewise, the
//!   shuffle-then-reconstruct order is bitwise identical to
//!   reconstructing both tiles first — it just moves 12 vectors instead
//!   of 18.
//!
//! [`Links`] is the runtime-selectable sum of the two, picked by the
//! `gauge.compression` config key; the operators in
//! [`crate::coordinator::operator`] store it so one monomorphized solver
//! stack serves both representations.

use crate::algebra::{Real, Su3};
use crate::field::compressed::{reconstruct_third_row, CT2};
use crate::field::{CompressedGaugeField, GaugeField};
use crate::lattice::{Dir, EoLayout, Parity, SiteCoord, CC2};

use super::eo::{shuffle, tile_slice};
use super::shift::LanePlan;

/// Gauge-link storage policy (the `gauge.compression` config key).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Compression {
    /// Full 18-real links, streamed as stored.
    #[default]
    None,
    /// Two-row 12-real links, third row rebuilt in-register.
    TwoRow,
}

impl Compression {
    /// Parse the config/CLI spelling (`none` | `two-row`).
    pub fn parse(s: &str) -> Result<Compression, String> {
        match s {
            "none" => Ok(Compression::None),
            "two-row" => Ok(Compression::TwoRow),
            other => Err(format!(
                "gauge compression must be \"none\" or \"two-row\" (got {other:?})"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Compression::None => "none",
            Compression::TwoRow => "two-row",
        }
    }

    /// Reals streamed per link under this policy (18 or 12).
    pub fn reals_per_link(self) -> usize {
        match self {
            Compression::None => CC2,
            Compression::TwoRow => CT2,
        }
    }
}

impl std::fmt::Display for Compression {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A provider of SU(3) link tiles for the stencil kernels.
///
/// `Sync` because kernel phases run tile-sharded on the worker team with
/// the source shared read-only across threads.
pub trait LinkSource<R: Real>: Sync {
    /// Reals streamed per link (18 full, 12 two-row) — the bytes/site
    /// model and the flop accounting key off this.
    fn reals_per_link(&self) -> usize;

    fn layout(&self) -> &EoLayout;

    /// The `CC2 * V` link tile for (dir, parity, tile): either borrowed
    /// straight from storage (`buf` untouched) or materialized into
    /// `buf` by two-row reconstruction. `buf` must hold `CC2 * V`
    /// values; `V` must equal the layout's `vlen`.
    fn link_tile<'a, const V: usize>(
        &'a self,
        dir: usize,
        p: Parity,
        tile: usize,
        buf: &'a mut [R],
    ) -> &'a [R];

    /// The backward-hop link tile: the lane shuffle of (`tile`, `nbr`)
    /// by `plan`, written into `buf` (`CC2 * V` values). The compressed
    /// source shuffles the 12 stored vectors and reconstructs in the
    /// shuffled tile — bitwise identical to shuffling a reconstructed
    /// pair, with a third less data moved.
    fn link_tile_shifted<const V: usize>(
        &self,
        dir: usize,
        p: Parity,
        tile: usize,
        nbr: usize,
        plan: &LanePlan,
        buf: &mut [R],
    );

    /// One link as an f64 matrix, for the per-site paths (EO1 halo pack,
    /// EO2 halo merge, observables). Compressed sources rebuild the
    /// third row in `R` first, so the value matches the reconstructed
    /// field's bitwise.
    fn site_link(&self, dir: Dir, p: Parity, s: SiteCoord) -> Su3;
}

impl<R: Real> LinkSource<R> for GaugeField<R> {
    #[inline(always)]
    fn reals_per_link(&self) -> usize {
        CC2
    }

    #[inline(always)]
    fn layout(&self) -> &EoLayout {
        &self.layout
    }

    #[inline(always)]
    fn link_tile<'a, const V: usize>(
        &'a self,
        dir: usize,
        p: Parity,
        tile: usize,
        _buf: &'a mut [R],
    ) -> &'a [R] {
        tile_slice::<R, V>(&self.data[dir][p.index()], tile, CC2)
    }

    #[inline(always)]
    fn link_tile_shifted<const V: usize>(
        &self,
        dir: usize,
        p: Parity,
        tile: usize,
        nbr: usize,
        plan: &LanePlan,
        buf: &mut [R],
    ) {
        let arr = &self.data[dir][p.index()];
        shuffle::<R, V>(
            buf,
            tile_slice::<R, V>(arr, tile, CC2),
            tile_slice::<R, V>(arr, nbr, CC2),
            plan,
            false,
            CC2,
        );
    }

    #[inline(always)]
    fn site_link(&self, dir: Dir, p: Parity, s: SiteCoord) -> Su3 {
        self.link(dir, p, s)
    }
}

impl<R: Real> LinkSource<R> for CompressedGaugeField<R> {
    #[inline(always)]
    fn reals_per_link(&self) -> usize {
        CT2
    }

    #[inline(always)]
    fn layout(&self) -> &EoLayout {
        &self.layout
    }

    #[inline(always)]
    fn link_tile<'a, const V: usize>(
        &'a self,
        dir: usize,
        p: Parity,
        tile: usize,
        buf: &'a mut [R],
    ) -> &'a [R] {
        let stored = tile_slice::<R, V>(&self.data[dir][p.index()], tile, CT2);
        buf[..CT2 * V].copy_from_slice(stored);
        reconstruct_third_row(buf, V);
        &buf[..CC2 * V]
    }

    #[inline(always)]
    fn link_tile_shifted<const V: usize>(
        &self,
        dir: usize,
        p: Parity,
        tile: usize,
        nbr: usize,
        plan: &LanePlan,
        buf: &mut [R],
    ) {
        let arr = &self.data[dir][p.index()];
        // shuffle only the stored rows, then rebuild in the shifted tile
        shuffle::<R, V>(
            buf,
            tile_slice::<R, V>(arr, tile, CT2),
            tile_slice::<R, V>(arr, nbr, CT2),
            plan,
            false,
            CT2,
        );
        reconstruct_third_row(buf, V);
    }

    #[inline(always)]
    fn site_link(&self, dir: Dir, p: Parity, s: SiteCoord) -> Su3 {
        self.link(dir, p, s)
    }
}

/// Runtime-selected link representation: the sum type the operators
/// store so `gauge.compression` can switch the whole solver stack
/// between full and two-row links without re-monomorphizing it.
#[derive(Clone, Debug)]
pub enum Links<R: Real = f32> {
    /// Full 18-real links (copy-through).
    Full(GaugeField<R>),
    /// Two-row 12-real links (in-tile reconstruction).
    TwoRow(CompressedGaugeField<R>),
}

impl<R: Real> Links<R> {
    /// Wrap a gauge field under the given compression policy. `TwoRow`
    /// compresses (drops the third row); the original field is consumed
    /// either way.
    pub fn from_gauge(u: GaugeField<R>, c: Compression) -> Links<R> {
        match c {
            Compression::None => Links::Full(u),
            Compression::TwoRow => Links::TwoRow(CompressedGaugeField::compress(&u)),
        }
    }

    pub fn compression(&self) -> Compression {
        match self {
            Links::Full(_) => Compression::None,
            Links::TwoRow(_) => Compression::TwoRow,
        }
    }

    /// Materialize a full gauge field: a clone for `Full`, the canonical
    /// third-row rebuild for `TwoRow` (the field the compressed kernels
    /// are bitwise equivalent to).
    pub fn to_gauge(&self) -> GaugeField<R> {
        match self {
            Links::Full(u) => u.clone(),
            Links::TwoRow(c) => c.reconstruct(),
        }
    }
}

impl<R: Real> LinkSource<R> for Links<R> {
    #[inline(always)]
    fn reals_per_link(&self) -> usize {
        match self {
            Links::Full(u) => LinkSource::<R>::reals_per_link(u),
            Links::TwoRow(c) => LinkSource::<R>::reals_per_link(c),
        }
    }

    #[inline(always)]
    fn layout(&self) -> &EoLayout {
        match self {
            Links::Full(u) => &u.layout,
            Links::TwoRow(c) => &c.layout,
        }
    }

    #[inline(always)]
    fn link_tile<'a, const V: usize>(
        &'a self,
        dir: usize,
        p: Parity,
        tile: usize,
        buf: &'a mut [R],
    ) -> &'a [R] {
        match self {
            Links::Full(u) => u.link_tile::<V>(dir, p, tile, buf),
            Links::TwoRow(c) => c.link_tile::<V>(dir, p, tile, buf),
        }
    }

    #[inline(always)]
    fn link_tile_shifted<const V: usize>(
        &self,
        dir: usize,
        p: Parity,
        tile: usize,
        nbr: usize,
        plan: &LanePlan,
        buf: &mut [R],
    ) {
        match self {
            Links::Full(u) => u.link_tile_shifted::<V>(dir, p, tile, nbr, plan, buf),
            Links::TwoRow(c) => c.link_tile_shifted::<V>(dir, p, tile, nbr, plan, buf),
        }
    }

    #[inline(always)]
    fn site_link(&self, dir: Dir, p: Parity, s: SiteCoord) -> Su3 {
        match self {
            Links::Full(u) => u.link(dir, p, s),
            Links::TwoRow(c) => c.link(dir, p, s),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dslash::shift::ShiftPlans;
    use crate::lattice::{Geometry, LatticeDims, Tiling};
    use crate::util::rng::Rng;

    fn geom() -> Geometry {
        Geometry::single_rank(
            LatticeDims::new(8, 4, 4, 4).unwrap(),
            Tiling::new(2, 2).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn compression_parse_roundtrip() {
        assert_eq!(Compression::parse("none").unwrap(), Compression::None);
        assert_eq!(Compression::parse("two-row").unwrap(), Compression::TwoRow);
        assert!(Compression::parse("one-row").is_err());
        assert_eq!(Compression::None.reals_per_link(), 18);
        assert_eq!(Compression::TwoRow.reals_per_link(), 12);
        assert_eq!(Compression::TwoRow.to_string(), "two-row");
    }

    #[test]
    fn compressed_tiles_match_reconstructed_field_bitwise() {
        const V: usize = 4;
        let g = geom();
        let mut rng = Rng::seeded(101);
        let u = GaugeField::<f64>::random(&g, &mut rng);
        let c = CompressedGaugeField::compress(&u);
        let full = c.reconstruct();
        let mut buf = vec![0.0f64; CC2 * V];
        let mut buf2 = vec![0.0f64; CC2 * V];
        for dir in 0..4 {
            for p in Parity::BOTH {
                for tile in [0usize, 3, full.layout.ntiles() - 1] {
                    let want = full.link_tile::<V>(dir, p, tile, &mut buf2).to_vec();
                    let got = c.link_tile::<V>(dir, p, tile, &mut buf);
                    assert_eq!(got, &want[..], "dir {dir} {p:?} tile {tile}");
                }
            }
        }
    }

    #[test]
    fn shifted_compressed_tiles_match_shifting_reconstructed() {
        const V: usize = 4;
        let g = geom();
        let mut rng = Rng::seeded(102);
        let u = GaugeField::<f32>::random(&g, &mut rng);
        let c = CompressedGaugeField::compress(&u);
        let full = c.reconstruct();
        let plans = ShiftPlans::new(g.tiling);
        let mut got = vec![0.0f32; CC2 * V];
        let mut want = vec![0.0f32; CC2 * V];
        for (dir, plan) in [(0usize, &plans.x_minus[0]), (1, &plans.y_minus)] {
            for p in Parity::BOTH {
                let (tile, nbr) = (1usize, 0usize);
                full.link_tile_shifted::<V>(dir, p, tile, nbr, plan, &mut want);
                c.link_tile_shifted::<V>(dir, p, tile, nbr, plan, &mut got);
                assert_eq!(got, want, "dir {dir} {p:?}");
            }
        }
    }

    #[test]
    fn links_enum_delegates() {
        const V: usize = 4;
        let g = geom();
        let mut rng = Rng::seeded(103);
        let u = GaugeField::<f32>::random(&g, &mut rng);
        let full = Links::from_gauge(u.clone(), Compression::None);
        let two = Links::from_gauge(u.clone(), Compression::TwoRow);
        assert_eq!(full.compression(), Compression::None);
        assert_eq!(two.compression(), Compression::TwoRow);
        assert_eq!(LinkSource::<f32>::reals_per_link(&full), 18);
        assert_eq!(LinkSource::<f32>::reals_per_link(&two), 12);
        // to_gauge of TwoRow is the projected field the kernels match
        let proj = two.to_gauge();
        let mut buf = vec![0.0f32; CC2 * V];
        let got = two.link_tile::<V>(2, Parity::Odd, 1, &mut buf).to_vec();
        let mut buf2 = vec![0.0f32; CC2 * V];
        let want = proj.link_tile::<V>(2, Parity::Odd, 1, &mut buf2).to_vec();
        assert_eq!(got, want);
    }
}
