//! Floating-point operation accounting.
//!
//! The paper (§2) quotes 1368 flop per lattice site for one application of
//! the full Wilson matrix in the QXS convention; every GFlops number in
//! the harness uses that convention so results are directly comparable
//! with Table 1 / Fig. 10. The *structural* count of our kernel is also
//! computed here (and tested) so the two conventions can be compared.

/// Paper/QXS convention: flop per site for one D_W application.
pub const QXS_FLOP_PER_SITE: u64 = crate::FLOP_PER_SITE;

/// Structural flop count of one (direction, sign) hop for one site:
/// projection (2 spins x 3 colors x 1 complex add) + SU(3) x half-spinor
/// (2 spins x 9 complex madds, 8 flop each) + reconstruction (4 spins x
/// 3 colors x 1 complex add).
pub const fn hop_flops() -> u64 {
    let project = 2 * 3 * 2;
    let su3 = 2 * 9 * 8;
    let reconstruct = 4 * 3 * 2;
    project + su3 + reconstruct
}

/// Structural flop per output site of one hopping block (8 hops).
pub const fn hopping_flops_per_site() -> u64 {
    8 * hop_flops()
}

/// Flops of one hopping-block application (`D_eo` or `D_oe`) over a half
/// lattice of `half_volume` sites, QXS convention.
///
/// Both blocks together visit every site once and the paper counts the
/// pair as one `D_W` at 1368 flop/site, so one block on `half_volume`
/// sites is `1368 * half_volume`.
pub fn hopping_block_flops(half_volume: usize) -> u64 {
    QXS_FLOP_PER_SITE * half_volume as u64
}

/// Flops of one even-odd preconditioned operator application
/// (M-hat = 1 - kappa^2 H_eo H_oe, Eq. 4): two hopping blocks plus the
/// axpy (2 flop per real component).
pub fn meo_flops(half_volume: usize) -> u64 {
    2 * hopping_block_flops(half_volume) + 2 * 24 * half_volume as u64
}

// ---- two-row link compression ------------------------------------------

/// Flops of rebuilding one two-row-compressed link's third row in
/// registers: 3 complex entries of `conj(row0 × row1)`, each 4 mul +
/// 3 add (re) and 4 mul + 3 add + 1 negate (im) = 15.
pub const TWO_ROW_RECONSTRUCT_FLOPS_PER_LINK: u64 = 3 * 15;

/// Extra flops one hopping block pays per output site when its links
/// are two-row compressed: 8 hops, one link rebuilt per hop.
pub fn two_row_hopping_flops(half_volume: usize) -> u64 {
    8 * TWO_ROW_RECONSTRUCT_FLOPS_PER_LINK * half_volume as u64
}

/// [`meo_flops`] with the link storage charged honestly: a two-row
/// source (12 reals per link) pays [`two_row_hopping_flops`] on each of
/// the two hopping blocks; a full source (18 reals) pays nothing extra.
/// The reconstruction work is the flops-for-bytes trade the roofline
/// makes free — but it is real arithmetic and the GFlops reports count
/// it.
pub fn meo_links_flops(half_volume: usize, reals_per_link: usize) -> u64 {
    let rebuild = if reals_per_link < 18 {
        2 * two_row_hopping_flops(half_volume)
    } else {
        0
    };
    meo_flops(half_volume) + rebuild
}

// ---- BLAS-1 accounting --------------------------------------------------
//
// The solvers charge every axpy/xpay sweep and every dot/norm reduction,
// not just the operator applies, so the GFlops a `SolveStats` reports is
// the rate of the whole iteration. `nreal` is the number of *real*
// components the sweep touches (one parity field = 24 per site, i.e.
// `FermionField::data.len()`).

/// Real components of a one-parity spinor field over `half_volume` sites.
pub fn spinor_reals(half_volume: usize) -> u64 {
    24 * half_volume as u64
}

/// `x += a y` with a real scalar: one madd per component.
pub fn axpy_flops(nreal: u64) -> u64 {
    2 * nreal
}

/// `x = a x + y` with a real scalar: one madd per component.
pub fn xpay_flops(nreal: u64) -> u64 {
    2 * nreal
}

/// `|x|²`: one madd per component.
pub fn norm2_flops(nreal: u64) -> u64 {
    2 * nreal
}

/// `Re⟨x, y⟩`: one madd per component.
pub fn dot_re_flops(nreal: u64) -> u64 {
    2 * nreal
}

/// `x += a y` with a complex scalar: a complex madd (8 flop) per pair.
pub fn caxpy_flops(nreal: u64) -> u64 {
    4 * nreal
}

/// Complex ⟨x, y⟩: a complex madd per pair.
pub fn cdot_flops(nreal: u64) -> u64 {
    4 * nreal
}

/// `x = a x` with a complex scalar: 6 flop per pair.
pub fn cscale_flops(nreal: u64) -> u64 {
    3 * nreal
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structural_close_to_qxs_convention() {
        // our structural count: 8 * (12 + 144 + 24) = 1440 per output site;
        // the QXS number (1368) differs only by convention details (<6%)
        assert_eq!(hopping_flops_per_site(), 1440);
        let ratio = hopping_flops_per_site() as f64 / QXS_FLOP_PER_SITE as f64;
        assert!((ratio - 1.0).abs() < 0.06, "ratio {ratio}");
    }

    #[test]
    fn block_flops_scale_with_volume() {
        assert_eq!(hopping_block_flops(100), 136_800);
        assert!(meo_flops(100) > 2 * hopping_block_flops(100));
    }

    #[test]
    fn two_row_reconstruction_charged_honestly() {
        // full links add nothing; two-row links pay 2 * 8 * 45 per site
        assert_eq!(meo_links_flops(100, 18), meo_flops(100));
        assert_eq!(
            meo_links_flops(100, 12),
            meo_flops(100) + 2 * 8 * 45 * 100
        );
        // the rebuild is small next to the hop itself (< 7% of QXS)
        let ratio = two_row_hopping_flops(100) as f64 / hopping_block_flops(100) as f64;
        assert!(ratio < 0.07, "ratio {ratio}");
    }

    #[test]
    fn blas1_accounting() {
        let n = spinor_reals(100);
        assert_eq!(n, 2400);
        assert_eq!(axpy_flops(n), 2 * n);
        assert_eq!(xpay_flops(n), 2 * n);
        assert_eq!(norm2_flops(n), 2 * n);
        assert_eq!(dot_re_flops(n), 2 * n);
        // complex ops: 8 (madd) and 6 (scale) flop per re/im pair
        assert_eq!(caxpy_flops(n), 8 * n / 2);
        assert_eq!(cdot_flops(n), 8 * n / 2);
        assert_eq!(cscale_flops(n), 6 * n / 2);
        // one meo apply dwarfs any single BLAS-1 sweep
        assert!(meo_flops(100) > caxpy_flops(n));
    }
}
