//! Floating-point operation accounting.
//!
//! The paper (§2) quotes 1368 flop per lattice site for one application of
//! the full Wilson matrix in the QXS convention; every GFlops number in
//! the harness uses that convention so results are directly comparable
//! with Table 1 / Fig. 10. The *structural* count of our kernel is also
//! computed here (and tested) so the two conventions can be compared.

/// Paper/QXS convention: flop per site for one D_W application.
pub const QXS_FLOP_PER_SITE: u64 = crate::FLOP_PER_SITE;

/// Structural flop count of one (direction, sign) hop for one site:
/// projection (2 spins x 3 colors x 1 complex add) + SU(3) x half-spinor
/// (2 spins x 9 complex madds, 8 flop each) + reconstruction (4 spins x
/// 3 colors x 1 complex add).
pub const fn hop_flops() -> u64 {
    let project = 2 * 3 * 2;
    let su3 = 2 * 9 * 8;
    let reconstruct = 4 * 3 * 2;
    project + su3 + reconstruct
}

/// Structural flop per output site of one hopping block (8 hops).
pub const fn hopping_flops_per_site() -> u64 {
    8 * hop_flops()
}

/// Flops of one hopping-block application (`D_eo` or `D_oe`) over a half
/// lattice of `half_volume` sites, QXS convention.
///
/// Both blocks together visit every site once and the paper counts the
/// pair as one `D_W` at 1368 flop/site, so one block on `half_volume`
/// sites is `1368 * half_volume`.
pub fn hopping_block_flops(half_volume: usize) -> u64 {
    QXS_FLOP_PER_SITE * half_volume as u64
}

/// Flops of one even-odd preconditioned operator application
/// (M-hat = 1 - kappa^2 H_eo H_oe, Eq. 4): two hopping blocks plus the
/// axpy (2 flop per real component).
pub fn meo_flops(half_volume: usize) -> u64 {
    2 * hopping_block_flops(half_volume) + 2 * 24 * half_volume as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structural_close_to_qxs_convention() {
        // our structural count: 8 * (12 + 144 + 24) = 1440 per output site;
        // the QXS number (1368) differs only by convention details (<6%)
        assert_eq!(hopping_flops_per_site(), 1440);
        let ratio = hopping_flops_per_site() as f64 / QXS_FLOP_PER_SITE as f64;
        assert!((ratio - 1.0).abs() < 0.06, "ratio {ratio}");
    }

    #[test]
    fn block_flops_scale_with_volume() {
        assert_eq!(hopping_block_flops(100), 136_800);
        assert!(meo_flops(100) > 2 * hopping_block_flops(100));
    }
}
