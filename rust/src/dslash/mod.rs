//! Wilson dslash kernels.
//!
//! * [`eo`] — the vectorized even-odd hopping kernel with lane-shuffle
//!   stencil shifts: the paper's contribution (its "ACLE" implementation).
//! * [`gather`] — the same operator through per-element gather/scatter
//!   access: the pathological variant Fig. 8 profiles "before" tuning.
//! * [`scalar`] — plain site-at-a-time baseline (the paper's "without
//!   ACLE" comparison, ~10x slower on A64FX).
//! * [`full`] — full Wilson matrix / even-odd preconditioned operator
//!   compositions on top of a hopping kernel.
//! * [`multi`] — the multi-RHS batched hopping: one gauge stream feeds
//!   N interleaved right-hand sides (block-field layout), with per-RHS
//!   fused store tails, dot capture and convergence masking.
//! * [`links`] — the [`links::LinkSource`] abstraction the hot kernels
//!   stream gauge tiles through: full 18-real links (copy-through) or
//!   two-row 12-real compressed links rebuilt in-register.
//! * [`shift`] — the `sel`/`tbl`/`ext` lane-shuffle engine.
//! * [`clover`] — site-local clover `D_ee`/`D_oo` blocks (QWS context).
//! * [`flops`] — flop accounting (QXS 1368 flop/site convention).

pub mod clover;
pub mod eo;
pub mod flops;
pub mod full;
pub mod gather;
pub mod links;
pub mod multi;
pub mod scalar;
pub mod shift;

pub use eo::{DotCapture, HoppingEo, StoreTail, WrapMode};
pub use links::{Compression, LinkSource, Links};
pub use multi::{MultiDotCapture, MultiStoreTail};
pub use gather::HoppingGather;
pub use scalar::HoppingScalar;
