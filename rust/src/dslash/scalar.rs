//! Plain scalar even-odd hopping — the paper's "without ACLE
//! implementation" baseline (§4.2: ~10x slower than the tuned kernel on
//! A64FX). Site-at-a-time, using the algebra structs; no lane vectors.
//!
//! Also serves as the in-crate correctness oracle for the vectorized
//! kernel (which is itself pinned to the Python reference via golden data).

use crate::algebra::{Real, Spinor, PROJ};
use crate::field::{FermionField, GaugeField};
use crate::lattice::{Dir, EvenOdd, Geometry, Parity, SiteCoord};

/// Scalar (site-wise) even-odd hopping operator.
#[derive(Clone, Debug)]
pub struct HoppingScalar {
    pub geom: Geometry,
}

impl HoppingScalar {
    pub fn new(geom: &Geometry) -> HoppingScalar {
        HoppingScalar { geom: *geom }
    }

    /// out = H_{p_out <- 1-p_out} psi, fully periodic on the local lattice.
    /// All site algebra runs in f64 regardless of the field precision `R`
    /// (the oracle property the vectorized kernels are checked against).
    pub fn apply<R: Real>(
        &self,
        out: &mut FermionField<R>,
        u: &GaugeField<R>,
        psi: &FermionField<R>,
        p_out: Parity,
    ) {
        let d = self.geom.local;
        let ext = [d.x, d.y, d.z, d.t];
        let p_in = p_out.flip();
        let sites: Vec<SiteCoord> = out.layout.sites().collect();
        for s in sites {
            let phi = EvenOdd::row_parity(s.y, s.z, s.t, p_out);
            let coords = [EvenOdd::lexical_x(s.ix, phi), s.y, s.z, s.t];
            let mut acc = Spinor::ZERO;
            for mu in 0..4 {
                // forward: (1 - g_mu) U_mu(x) psi(x + mu)
                let mut cf = coords;
                cf[mu] = (cf[mu] + 1) % ext[mu];
                let nbr = SiteCoord {
                    t: cf[3],
                    z: cf[2],
                    y: cf[1],
                    ix: EvenOdd::compact_x(cf[0]),
                };
                let e = &PROJ[mu][0];
                let h = e.project(&psi.site(nbr));
                let w = h.link_mul(&u.link(Dir::from_index(mu), p_out, s));
                e.reconstruct_accum(&mut acc, &w);

                // backward: (1 + g_mu) U_mu(x - mu)^dag psi(x - mu)
                let mut cb = coords;
                cb[mu] = (cb[mu] + ext[mu] - 1) % ext[mu];
                let nbr = SiteCoord {
                    t: cb[3],
                    z: cb[2],
                    y: cb[1],
                    ix: EvenOdd::compact_x(cb[0]),
                };
                let e = &PROJ[mu][1];
                let h = e.project(&psi.site(nbr));
                let w = h.link_adj_mul(&u.link(Dir::from_index(mu), p_in, nbr));
                e.reconstruct_accum(&mut acc, &w);
            }
            out.set_site(s, &acc);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::{LatticeDims, Tiling};
    use crate::util::rng::Rng;

    fn setup() -> (Geometry, GaugeField, FermionField) {
        let geom = Geometry::single_rank(
            LatticeDims::new(4, 4, 4, 4).unwrap(),
            Tiling::new(2, 2).unwrap(),
        )
        .unwrap();
        let mut rng = Rng::seeded(77);
        let u = GaugeField::random(&geom, &mut rng);
        let psi = FermionField::gaussian(&geom, &mut rng);
        (geom, u, psi)
    }

    #[test]
    fn unit_gauge_constant_field_gives_8x() {
        // U = 1, psi = const: H psi = sum of the 8 (1 -+ g) projectors = 8 psi
        let geom = Geometry::single_rank(
            LatticeDims::new(4, 4, 4, 4).unwrap(),
            Tiling::new(2, 2).unwrap(),
        )
        .unwrap();
        let u: GaugeField = GaugeField::unit(&geom);
        let mut psi: FermionField = FermionField::zeros(&geom);
        psi.fill(1.0);
        let mut out = FermionField::zeros(&geom);
        HoppingScalar::new(&geom).apply(&mut out, &u, &psi, Parity::Even);
        let mut want = psi.clone();
        want.scale(8.0);
        want.axpy(-1.0, &out);
        assert!(want.norm2() < 1e-8, "residual {}", want.norm2());
    }

    #[test]
    fn linearity() {
        let (geom, u, psi1) = setup();
        let mut rng = Rng::seeded(78);
        let psi2 = FermionField::gaussian(&geom, &mut rng);
        let hop = HoppingScalar::new(&geom);
        let mut combined = psi1.clone();
        combined.scale(0.5);
        combined.axpy(1.0, &psi2);
        let mut out_comb = FermionField::zeros(&geom);
        hop.apply(&mut out_comb, &u, &combined, Parity::Odd);
        let mut out1 = FermionField::zeros(&geom);
        hop.apply(&mut out1, &u, &psi1, Parity::Odd);
        let mut out2 = FermionField::zeros(&geom);
        hop.apply(&mut out2, &u, &psi2, Parity::Odd);
        out1.scale(0.5);
        out1.axpy(1.0, &out2);
        out1.axpy(-1.0, &out_comb);
        assert!(out1.norm2() < 1e-6, "residual {}", out1.norm2());
    }

    #[test]
    fn gamma5_hermiticity_of_hopping() {
        // <x, H_oe y> = <H_eo g5 x g5 ... : for the hopping blocks,
        // (H_oe)^dag = g5 H_eo g5. Verify <x_o, H_oe y_e> = <g5 H_eo g5 x_o, y_e>.
        let (geom, u, y_e) = setup();
        let mut rng = Rng::seeded(79);
        let x_o = FermionField::gaussian(&geom, &mut rng);
        let hop = HoppingScalar::new(&geom);

        let mut hy = FermionField::zeros(&geom);
        hop.apply(&mut hy, &u, &y_e, Parity::Odd);
        let lhs = x_o.dot(&hy);

        let mut g5x = x_o.clone();
        g5x.gamma5();
        let mut hg5x = FermionField::zeros(&geom);
        hop.apply(&mut hg5x, &u, &g5x, Parity::Even);
        hg5x.gamma5();
        let rhs = hg5x.dot(&y_e);

        assert!(
            (lhs.re - rhs.re).abs() < 1e-4 && (lhs.im - rhs.im).abs() < 1e-4,
            "lhs {lhs:?} rhs {rhs:?}"
        );
    }
}
