//! Fig. 10: weak scaling of the even-odd Wilson matrix multiplication to
//! 512 nodes for three per-process local lattices (4x4 tiling).
//!
//! Two layers, per the substitution rule (DESIGN.md section 4):
//! 1. *real measurement* of the per-rank phase times (EO1 / bulk / EO2)
//!    and message sizes on this host, through the actual pipeline;
//! 2. the TofuD discrete-event model projects those onto 1..512 nodes
//!    with the paper's neighbor-only rank maps (comm cost independent of
//!    node count -> the flat curve the paper reports).
//!
//! Additionally, small real multi-rank runs (in-process threads) verify
//! that per-rank throughput stays flat where the host can actually run
//! them.

use crate::comm::halo::HALF_SPINOR_F32;
use crate::comm::netmodel::{weak_scaling_gflops_per_node, NetModel, RankCompute};
use crate::comm::run_world;
use crate::coordinator::{BarrierKind, DistHopping, Eo2Schedule, Phase, Profiler, Team};
use crate::field::{FermionField, GaugeField};
use crate::lattice::{Geometry, LatticeDims, Parity, Tiling};
use crate::util::rng::Rng;
use crate::util::tables::Table;

use super::Opts;

/// Measured phase profile of one rank's hopping application.
pub fn measure_phases(dims: LatticeDims, opts: &Opts) -> (RankCompute, [usize; 4]) {
    let tiling = Tiling::new(4, 4).unwrap();
    let geom = Geometry::single_rank(dims, tiling).unwrap();
    let (report, plans_bytes) = run_world(1, |_, comm| {
        let mut rng = Rng::seeded(1010);
        let u: GaugeField = GaugeField::random(&geom, &mut rng);
        let psi: FermionField = FermionField::gaussian(&geom, &mut rng);
        let mut out = FermionField::zeros(&geom);
        let dist = DistHopping::new(&geom, true, opts.threads, Eo2Schedule::Balanced);
        let mut team = Team::new(opts.threads, BarrierKind::Sleep);
        let prof = Profiler::new(opts.threads);
        for _ in 0..opts.iters {
            dist.hopping(&mut out, &u, &psi, Parity::Odd, comm, &mut team, &prof);
        }
        let plans = dist.plans(Parity::Odd);
        let bytes: [usize; 4] =
            std::array::from_fn(|d| plans.face_count[d] * HALF_SPINOR_F32 * 4);
        (prof.snapshot(), bytes)
    })
    .remove(0);

    // wall time of a phase ~ max over threads (they run concurrently);
    // normalize per application
    let per_iter = |phase: Phase| -> f64 {
        let max = report
            .times
            .iter()
            .map(|t| t[phase as usize])
            .fold(0.0, f64::max);
        max / opts.iters as f64
    };
    (
        RankCompute {
            eo1: per_iter(Phase::Eo1),
            bulk: per_iter(Phase::Bulk),
            eo2: per_iter(Phase::Eo2) + per_iter(Phase::CommWait),
        },
        plans_bytes,
    )
}

pub struct Fig10Result {
    pub report: String,
    /// per (lattice, node-count) projected per-node GFlops
    pub series: Vec<(LatticeDims, Vec<(usize, f64)>)>,
}

pub fn run(opts: Opts) -> Fig10Result {
    let lattices = super::table1::paper_lattices(opts.quick);
    let nodes = vec![1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512];
    let net = NetModel::tofu_d();
    let mut series = Vec::new();
    let mut table = Table::new(
        "Fig 10: weak scaling — projected per-node GFlops (TofuD model over measured per-rank phases; paper: flat to 512 nodes)",
        &["local lattice", "nodes", "GFlops/node"],
    );
    for dims in lattices {
        // one hopping block covers half the sites; the matrix = 2 blocks.
        // Measure one block and count its flops accordingly.
        let (compute, bytes) = measure_phases(dims, &opts);
        let flops_per_rank = crate::FLOP_PER_SITE * dims.half_volume() as u64;
        let s =
            weak_scaling_gflops_per_node(&nodes, 4, compute, bytes, flops_per_rank, &net);
        for &(n, g) in &s {
            table.row(vec![dims.to_string(), n.to_string(), format!("{g:.2}")]);
        }
        series.push((dims, s));
    }

    let mut report = table.render();
    // flatness check (the paper's key claim)
    for (dims, s) in &series {
        let multi: Vec<f64> = s.iter().filter(|(n, _)| *n > 1).map(|(_, g)| *g).collect();
        let max = multi.iter().cloned().fold(f64::MIN, f64::max);
        let min = multi.iter().cloned().fold(f64::MAX, f64::min);
        report.push_str(&format!(
            "shape: {dims}: per-node perf varies {:.2}% across 2..512 nodes (paper: ~flat)\n",
            (max - min) / max * 100.0
        ));
    }
    Fig10Result { report, series }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn projection_runs_and_is_flat() {
        let r = run(Opts {
            iters: 3,
            threads: 1,
            quick: true,
        });
        assert_eq!(r.series.len(), 2);
        for (_, s) in &r.series {
            assert_eq!(s.len(), 10);
            let multi: Vec<f64> =
                s.iter().filter(|(n, _)| *n > 1).map(|(_, g)| *g).collect();
            let max = multi.iter().cloned().fold(f64::MIN, f64::max);
            let min = multi.iter().cloned().fold(f64::MAX, f64::min);
            assert!((max - min) / max < 0.05, "not flat: {s:?}");
        }
    }
}
