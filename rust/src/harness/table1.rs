//! Table 1: effect of the 2D SIMD tiling on the even-odd Wilson matrix
//! multiplication (single precision).
//!
//! Paper setup: four MPI ranks per node ([1,1,2,2]), per-process lattices
//! 16x16x8x8, 64x16x8x4, 64x32x16x8; tilings 16x1, 8x2, 4x4, 2x8;
//! communication enforced in all four directions; 1000 multiplications.
//! The 16x1 tiling is unavailable on the first lattice (XH = 8 < 16) —
//! the paper's dash.
//!
//! On this host we run one rank (the decomposition is SPMD-symmetric, so
//! per-rank throughput is the per-node number divided by 4) with the
//! communication path forced in all directions, exactly as the paper does
//! for its self-process sends.

use crate::comm::run_world;
use crate::coordinator::{BarrierKind, DistHopping, Eo2Schedule, Profiler, Team};
use crate::field::{FermionField, GaugeField};
use crate::lattice::{Geometry, LatticeDims, Parity, Tiling};
use crate::util::rng::Rng;
use crate::util::tables::Table;
use crate::util::timer::Stopwatch;

use super::Opts;

/// One measured cell of Table 1.
#[derive(Clone, Debug)]
pub struct Cell {
    pub lattice: LatticeDims,
    pub tiling: Tiling,
    /// per-rank sustained GFlops (QXS flop convention); None = unavailable
    pub gflops: Option<f64>,
}

/// The paper's per-process lattice list.
pub fn paper_lattices(quick: bool) -> Vec<LatticeDims> {
    if quick {
        vec![
            LatticeDims::new(16, 16, 4, 4).unwrap(),
            LatticeDims::new(32, 16, 4, 4).unwrap(),
        ]
    } else {
        vec![
            LatticeDims::new(16, 16, 8, 8).unwrap(),
            LatticeDims::new(64, 16, 8, 4).unwrap(),
            LatticeDims::new(64, 32, 16, 8).unwrap(),
        ]
    }
}

/// Measure one (lattice, tiling) cell: `iters` applications of the
/// even-odd matrix (both hopping blocks) through the full EO1/bulk/EO2
/// pipeline with forced self-communication.
pub fn measure_cell(
    dims: LatticeDims,
    tiling: Tiling,
    iters: usize,
    threads: usize,
) -> Option<f64> {
    let geom = Geometry::single_rank(dims, tiling).ok()?;
    let secs = run_world(1, |_, comm| {
        let mut rng = Rng::seeded(2023);
        let u: GaugeField = GaugeField::random(&geom, &mut rng);
        let psi_e: FermionField = FermionField::gaussian(&geom, &mut rng);
        let mut out_o = FermionField::zeros(&geom);
        let mut out_e = FermionField::zeros(&geom);
        let dist = DistHopping::new(&geom, true, threads, Eo2Schedule::Uniform);
        let mut team = Team::new(threads, BarrierKind::Sleep);
        let prof = Profiler::new(threads);
        // warmup
        dist.hopping(&mut out_o, &u, &psi_e, Parity::Odd, comm, &mut team, &prof);
        let sw = Stopwatch::start();
        for _ in 0..iters {
            dist.hopping(&mut out_o, &u, &psi_e, Parity::Odd, comm, &mut team, &prof);
            dist.hopping(&mut out_e, &u, &out_o, Parity::Even, comm, &mut team, &prof);
        }
        sw.secs()
    })[0];
    // one iteration = both blocks = 1368 flop x full local volume
    let flops = crate::FLOP_PER_SITE as f64 * dims.volume() as f64 * iters as f64;
    Some(flops / secs / 1e9)
}

/// Run the full sweep and render the paper-format table.
pub fn run(opts: Opts) -> (String, Vec<Cell>) {
    let tilings = Tiling::table1_sweep();
    let mut cells = Vec::new();
    let mut table = Table::new(
        "Table 1: 2D tiling sweep, even-odd Wilson matrix, f32 (per-rank GFlops; paper reports per-node = 4 ranks)",
        &["lattice size/process", "16x1", "8x2", "4x4", "2x8"],
    );
    for dims in paper_lattices(opts.quick) {
        let mut row = vec![dims.to_string()];
        for &tiling in &tilings {
            let gflops = measure_cell(dims, tiling, opts.iters, opts.threads);
            row.push(match gflops {
                Some(g) => format!("{g:.2}"),
                None => "-".to_string(),
            });
            cells.push(Cell {
                lattice: dims,
                tiling,
                gflops,
            });
        }
        table.row(row);
    }
    let mut out = table.render();
    out.push_str(&shape_summary(&cells));
    (out, cells)
}

/// The paper's qualitative claims about this table, evaluated on our data.
fn shape_summary(cells: &[Cell]) -> String {
    let mut out = String::new();
    // claim 1: the smallest (cache-resident) lattice is fastest
    let mut by_lattice: Vec<(LatticeDims, f64)> = Vec::new();
    for c in cells {
        if let Some(g) = c.gflops {
            match by_lattice.iter_mut().find(|(d, _)| *d == c.lattice) {
                Some((_, best)) => *best = best.max(g),
                None => by_lattice.push((c.lattice, g)),
            }
        }
    }
    if by_lattice.len() > 1 {
        let first = by_lattice[0];
        let best_other = by_lattice[1..]
            .iter()
            .cloned()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        out.push_str(&format!(
            "shape: smallest (cache-resident) lattice best? {} ({}: {:.2} vs best larger {}: {:.2}; paper: clearly yes — 24 MiB fits A64FX L2)\n",
            first.1 >= best_other.1,
            first.0,
            first.1,
            best_other.0,
            best_other.1
        ));
    }
    // claim 2: no strong tiling preference (spread across tilings small)
    for (dims, _) in &by_lattice {
        let vals: Vec<f64> = cells
            .iter()
            .filter(|c| c.lattice == *dims)
            .filter_map(|c| c.gflops)
            .collect();
        if vals.len() > 1 {
            let max = vals.iter().cloned().fold(f64::MIN, f64::max);
            let min = vals.iter().cloned().fold(f64::MAX, f64::min);
            out.push_str(&format!(
                "shape: tiling spread on {dims}: {:.1}% (paper: no significant preference)\n",
                (max - min) / max * 100.0
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_cell_measures() {
        let g = measure_cell(
            LatticeDims::new(8, 4, 4, 4).unwrap(),
            Tiling::new(2, 2).unwrap(),
            2,
            1,
        );
        assert!(g.unwrap() > 0.0);
    }

    #[test]
    fn unavailable_tiling_is_none() {
        // 16x1 tiling on NX=16: XH = 8 < 16 -> None (the paper's dash)
        let g = measure_cell(
            LatticeDims::new(16, 16, 4, 4).unwrap(),
            Tiling::new(16, 1).unwrap(),
            1,
            1,
        );
        assert!(g.is_none());
    }
}
