//! Fig. 8: cycle accounting of the bulk kernel before/after tuning.
//!
//! The paper's "before" is compiler-generated gather-load/scatter-store
//! from a leftover portable loop nest, which made the kernel L1-bound;
//! "after" replaces it with explicit SIMD shuffles. We profile
//! [`HoppingGather`] (the deliberately gather-shaped variant) against
//! [`HoppingEo`] (the shuffle kernel) under the same thread team and
//! render per-thread stacked time bars.

use crate::coordinator::team::{chunk_range, SendPtr};
use crate::coordinator::{BarrierKind, Phase, Profiler, Team};
use crate::field::{FermionField, GaugeField};
use crate::lattice::{Geometry, LatticeDims, Parity, Tiling, SC2};
use crate::util::rng::Rng;

use super::Opts;

pub struct Fig8Result {
    pub report: String,
    /// total bulk seconds, gather variant
    pub before_secs: f64,
    /// total bulk seconds, shuffle variant
    pub after_secs: f64,
}

/// Profile both bulk variants on the paper's per-process lattice.
pub fn run(opts: Opts) -> Fig8Result {
    // paper: 16^4 global over 4 ranks = 16x16x8x8 per process
    let dims = if opts.quick {
        LatticeDims::new(16, 16, 4, 4).unwrap()
    } else {
        LatticeDims::new(16, 16, 8, 8).unwrap()
    };
    let tiling = Tiling::new(4, 4).unwrap();
    let geom = Geometry::single_rank(dims, tiling).unwrap();
    let mut rng = Rng::seeded(88);
    let u: GaugeField = GaugeField::random(&geom, &mut rng);
    let psi: FermionField = FermionField::gaussian(&geom, &mut rng);
    let mut out = FermionField::zeros(&geom);
    let mut team = Team::new(opts.threads, BarrierKind::Sleep);

    let shuffle = crate::dslash::HoppingEo::new(&geom);
    let gather = crate::dslash::HoppingGather::new(&geom);
    let layout = shuffle.layout;
    let ntiles = layout.ntiles();
    let tile_f32 = SC2 * layout.vlen();

    let mut profile = |use_gather: bool| -> (String, f64) {
        let prof = Profiler::new(opts.threads);
        let out_ptr = SendPtr(out.data.as_mut_ptr());
        let n = opts.threads;
        for _ in 0..opts.iters {
            team.parallel(|tid| {
                prof.scope(tid, Phase::Bulk, || {
                    let (b, e) = chunk_range(ntiles, tid, n);
                    if b == e {
                        return;
                    }
                    // SAFETY: disjoint tile ranges per thread.
                    let out_tiles = unsafe {
                        out_ptr.slice_mut(b * tile_f32, (e - b) * tile_f32)
                    };
                    if use_gather {
                        gather.apply_tiles(out_tiles, &u, &psi, Parity::Odd, b, e);
                    } else {
                        shuffle.apply_tiles(out_tiles, &u, &psi, Parity::Odd, b, e);
                    }
                });
            });
        }
        let report = prof.snapshot();
        let total = report.phase_total(Phase::Bulk);
        let title = if use_gather {
            "Fig 8 (top): bulk BEFORE tuning — gather/scatter variant"
        } else {
            "Fig 8 (bottom): bulk AFTER tuning — lane-shuffle (sel/tbl/ext) variant"
        };
        (report.render(title), total)
    };

    let (before_chart, before_secs) = profile(true);
    let (after_chart, after_secs) = profile(false);

    let mut report = String::new();
    report.push_str(&before_chart);
    report.push('\n');
    report.push_str(&after_chart);
    report.push_str(&format!(
        "\nshape: tuned kernel speedup = {:.2}x (paper: the gather variant was the whole-kernel bottleneck via L1 busy)\n",
        before_secs / after_secs
    ));
    Fig8Result {
        report,
        before_secs,
        after_secs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_variant_slower() {
        let r = run(Opts {
            iters: 2,
            threads: 1,
            quick: true,
        });
        assert!(
            r.before_secs > r.after_secs,
            "gather {} vs shuffle {}",
            r.before_secs,
            r.after_secs
        );
        assert!(r.report.contains("Fig 8"));
    }
}
