//! Fig. 9: per-thread cycle accounting of the EO1 (pack) and EO2 (unpack)
//! kernels, exposing the EO2 load imbalance, plus the balanced-EO2
//! extension the paper proposes as future work.
//!
//! The imbalance mechanism (paper §4.1): EO2 is one flat loop over all
//! local sites, split uniformly over threads; in canonical (t,z,y,x)
//! order the *last* thread owns the high-t slab, whose sites all import
//! from the upward t-process and pay the 3x3 link multiplication.

use crate::comm::run_world;
use crate::coordinator::{BarrierKind, DistHopping, Eo2Schedule, Phase, Profiler, Team};
use crate::field::{FermionField, GaugeField};
use crate::lattice::{Geometry, LatticeDims, Parity, Tiling};
use crate::util::rng::Rng;

use super::Opts;

pub struct Fig9Result {
    pub report: String,
    /// max/mean thread-time imbalance of EO2, uniform schedule
    pub eo2_imbalance_uniform: f64,
    /// same with the cost-balanced schedule
    pub eo2_imbalance_balanced: f64,
    /// EO1 imbalance (should stay near 1)
    pub eo1_imbalance: f64,
    /// is the *last* thread the heaviest in EO2 (paper: thread 11)?
    pub last_thread_heaviest: bool,
}

pub fn run(opts: Opts) -> Fig9Result {
    let dims = if opts.quick {
        LatticeDims::new(16, 16, 4, 4).unwrap()
    } else {
        LatticeDims::new(16, 16, 8, 8).unwrap()
    };
    let tiling = Tiling::new(4, 4).unwrap();
    let geom = Geometry::single_rank(dims, tiling).unwrap();

    let profile = |schedule: Eo2Schedule| {
        run_world(1, |_, comm| {
            let mut rng = Rng::seeded(99);
            let u: GaugeField = GaugeField::random(&geom, &mut rng);
            let psi: FermionField = FermionField::gaussian(&geom, &mut rng);
            let mut out = FermionField::zeros(&geom);
            let dist = DistHopping::new(&geom, true, opts.threads, schedule);
            let mut team = Team::new(opts.threads, BarrierKind::Sleep);
            let prof = Profiler::new(opts.threads);
            for _ in 0..opts.iters {
                dist.hopping(&mut out, &u, &psi, Parity::Odd, comm, &mut team, &prof);
            }
            prof.snapshot()
        })
        .remove(0)
    };

    let uniform = profile(Eo2Schedule::Uniform);
    let balanced = profile(Eo2Schedule::Balanced);

    let eo2_vals: Vec<f64> = uniform
        .times
        .iter()
        .map(|t| t[Phase::Eo2 as usize])
        .collect();
    let last_thread_heaviest = eo2_vals
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i == eo2_vals.len() - 1)
        .unwrap_or(false);

    let mut report = String::new();
    report.push_str(&uniform.render(
        "Fig 9: EO1 (pack) + EO2 (unpack) per-thread accounting — uniform site split",
    ));
    report.push('\n');
    report.push_str(&balanced.render(
        "Fig 9 (extension): cost-balanced EO2 split (the paper's proposed future work)",
    ));
    report.push_str(&format!(
        "\nshape: EO1 imbalance {:.2} (paper: balanced), EO2 imbalance {:.2} (paper: significant, last thread heaviest: {}), balanced-EO2 imbalance {:.2}\n",
        uniform.imbalance(Phase::Eo1),
        uniform.imbalance(Phase::Eo2),
        last_thread_heaviest,
        balanced.imbalance(Phase::Eo2),
    ));

    Fig9Result {
        report,
        eo2_imbalance_uniform: uniform.imbalance(Phase::Eo2),
        eo2_imbalance_balanced: balanced.imbalance(Phase::Eo2),
        eo1_imbalance: uniform.imbalance(Phase::Eo1),
        last_thread_heaviest,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eo2_imbalance_reproduced_and_fixed() {
        // wall-clock thread times on an oversubscribed host are noisy, so
        // assert the paper's *shape* with slack: uniform splitting shows a
        // clear imbalance, and the cost-balanced split does not regress
        // beyond noise. The exact cost-level guarantee is asserted
        // deterministically in comm::balance.
        let r = run(Opts {
            iters: 16,
            threads: 4,
            quick: true,
        });
        assert!(
            r.eo2_imbalance_uniform > 1.15,
            "uniform EO2 should be imbalanced: {}",
            r.eo2_imbalance_uniform
        );
        assert!(
            r.eo2_imbalance_balanced < r.eo2_imbalance_uniform * 1.25,
            "balanced schedule regressed: {} vs {}",
            r.eo2_imbalance_balanced,
            r.eo2_imbalance_uniform
        );
    }
}
