//! FLIB_BARRIER ablation (paper §4): enabling the hardware barrier within
//! a CMG is worth ~20% at the smallest lattice. We compare the spin
//! barrier (hardware-barrier analog) against the sleeping barrier on the
//! distributed hopping at the small, barrier-sensitive lattice size.

use crate::comm::run_world;
use crate::coordinator::{BarrierKind, DistHopping, Eo2Schedule, Profiler, Team};
use crate::field::{FermionField, GaugeField};
use crate::lattice::{Geometry, LatticeDims, Parity, Tiling};
use crate::util::rng::Rng;
use crate::util::tables::Table;
use crate::util::timer::Stopwatch;

use super::Opts;

pub struct BarrierResult {
    pub report: String,
    pub spin_secs: f64,
    pub sleep_secs: f64,
}

fn measure(kind: BarrierKind, geom: &Geometry, opts: &Opts) -> f64 {
    run_world(1, |_, comm| {
        let mut rng = Rng::seeded(777);
        let u: GaugeField = GaugeField::random(geom, &mut rng);
        let psi: FermionField = FermionField::gaussian(geom, &mut rng);
        let mut out = FermionField::zeros(geom);
        let dist = DistHopping::new(geom, true, opts.threads, Eo2Schedule::Uniform);
        let mut team = Team::new(opts.threads, kind);
        let prof = Profiler::new(opts.threads);
        dist.hopping(&mut out, &u, &psi, Parity::Odd, comm, &mut team, &prof);
        let sw = Stopwatch::start();
        for _ in 0..opts.iters {
            dist.hopping(&mut out, &u, &psi, Parity::Odd, comm, &mut team, &prof);
        }
        sw.secs()
    })[0]
}

pub fn run(opts: Opts) -> BarrierResult {
    // small lattice: many barriers per unit of work, as in the paper's
    // "about 20% at our smallest lattice size"
    let dims = LatticeDims::new(8, 8, 4, 4).unwrap();
    let geom = Geometry::single_rank(dims, Tiling::new(4, 4).unwrap()).unwrap();
    let spin = measure(BarrierKind::Spin, &geom, &opts);
    let sleep = measure(BarrierKind::Sleep, &geom, &opts);
    let mut table = Table::new(
        "Barrier ablation (FLIB_BARRIER=HARD analog; paper: ~20% at the smallest lattice)",
        &["barrier", "seconds", "relative"],
    );
    table.row(vec!["spin (HARD analog)".into(), format!("{spin:.4}"), "1.00".into()]);
    table.row(vec![
        "sleep (soft analog)".into(),
        format!("{sleep:.4}"),
        format!("{:.2}", sleep / spin),
    ]);
    BarrierResult {
        report: table.render(),
        spin_secs: spin,
        sleep_secs: sleep,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_barriers_complete() {
        let r = run(Opts {
            iters: 3,
            threads: 2,
            quick: true,
        });
        assert!(r.spin_secs > 0.0 && r.sleep_secs > 0.0);
    }
}
