//! Benchmark harness: one module per table/figure of the paper's
//! evaluation (DESIGN.md section 6 experiment index).
//!
//! Every module regenerates the corresponding artifact's rows/series on
//! this host and prints them in the paper's format, alongside the
//! A64FX-projected numbers per the substitution rule. Entry points are
//! reachable both from `cargo bench` targets and the `lqcd` CLI.

pub mod acle;
pub mod barrier;
pub mod fig10;
pub mod fig8;
pub mod fig9;
pub mod table1;

/// Common options for harness runs.
#[derive(Clone, Copy, Debug)]
pub struct Opts {
    /// multiplications per measurement (paper: 1000)
    pub iters: usize,
    /// threads per rank (paper: 12)
    pub threads: usize,
    /// shrink lattices/iterations for CI-speed runs
    pub quick: bool,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            iters: 50,
            threads: 4,
            quick: false,
        }
    }
}
