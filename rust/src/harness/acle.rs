//! §4.2 text claim: the code "without ACLE implementation" (plain arrays
//! + hoped-for autovectorization) runs ~10x slower than the tuned SIMD
//! version on A64FX (~30 vs ~400 GFlops). We compare the plain scalar
//! site-wise kernel against the lane-vectorized kernel single-threaded,
//! plus the gather variant for context.

use crate::dslash::{HoppingEo, HoppingGather, HoppingScalar};
use crate::field::{FermionField, GaugeField};
use crate::lattice::{Geometry, LatticeDims, Parity, Tiling};
use crate::util::rng::Rng;
use crate::util::tables::Table;
use crate::util::timer::Bench;

use super::Opts;

pub struct AcleResult {
    pub report: String,
    pub vectorized_gflops: f64,
    pub scalar_gflops: f64,
    pub gather_gflops: f64,
}

pub fn run(opts: Opts) -> AcleResult {
    let dims = if opts.quick {
        LatticeDims::new(8, 8, 4, 4).unwrap()
    } else {
        LatticeDims::new(16, 16, 8, 8).unwrap()
    };
    let geom = Geometry::single_rank(dims, Tiling::new(4, 4).unwrap()).unwrap();
    let mut rng = Rng::seeded(4242);
    let u: GaugeField = GaugeField::random(&geom, &mut rng);
    let psi: FermionField = FermionField::gaussian(&geom, &mut rng);
    let mut out = FermionField::zeros(&geom);
    let flops = crate::FLOP_PER_SITE as f64 * dims.half_volume() as f64 * opts.iters as f64;

    let bench = Bench::new(1, 3);
    let vec_kernel = HoppingEo::new(&geom);
    let r_vec = bench.run(|| {
        for _ in 0..opts.iters {
            vec_kernel.apply(&mut out, &u, &psi, Parity::Odd);
        }
        Some(flops)
    });
    let scalar_kernel = HoppingScalar::new(&geom);
    let r_scalar = bench.run(|| {
        for _ in 0..opts.iters {
            scalar_kernel.apply(&mut out, &u, &psi, Parity::Odd);
        }
        Some(flops)
    });
    let gather_kernel = HoppingGather::new(&geom);
    let r_gather = bench.run(|| {
        for _ in 0..opts.iters {
            gather_kernel.apply(&mut out, &u, &psi, Parity::Odd);
        }
        Some(flops)
    });

    let (v, s, g) = (
        r_vec.gflops().unwrap(),
        r_scalar.gflops().unwrap(),
        r_gather.gflops().unwrap(),
    );
    let mut table = Table::new(
        "ACLE vs plain (paper §4.2: ~10x on A64FX; we accept 3-15x on x86)",
        &["kernel", "GFlops", "vs plain"],
    );
    table.row(vec!["lane-shuffle (ACLE analog)".into(), format!("{v:.2}"), format!("{:.1}x", v / s)]);
    table.row(vec!["gather variant (Fig 8 before)".into(), format!("{g:.2}"), format!("{:.1}x", g / s)]);
    table.row(vec!["plain scalar (no-ACLE analog)".into(), format!("{s:.2}"), "1.0x".into()]);
    AcleResult {
        report: table.render(),
        vectorized_gflops: v,
        scalar_gflops: s,
        gather_gflops: g,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vectorized_beats_scalar() {
        let r = run(Opts {
            iters: 2,
            threads: 1,
            quick: true,
        });
        assert!(
            r.vectorized_gflops > r.scalar_gflops,
            "vec {} vs scalar {}",
            r.vectorized_gflops,
            r.scalar_gflops
        );
    }
}
