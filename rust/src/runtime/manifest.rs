//! `artifacts/manifest.json` reader: which HLO-text artifacts exist, their
//! I/O shapes, and the lattice they were lowered for.

use std::path::{Path, PathBuf};

use crate::util::error::{anyhow, bail, Context, Result};

use crate::lattice::LatticeDims;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dims: LatticeDims,
    pub cg_tol: f64,
    pub cg_maxiter: usize,
    pub artifacts: Vec<ArtifactSpec>,
    pub dir: PathBuf,
}

fn tensor_spec(j: &Json) -> Result<TensorSpec> {
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("tensor spec missing shape"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad shape entry")))
        .collect::<Result<Vec<usize>>>()?;
    let dtype = j
        .get("dtype")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("tensor spec missing dtype"))?
        .to_string();
    Ok(TensorSpec { shape, dtype })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`?)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;

        let dims_arr = j
            .get("dims")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing dims"))?;
        if dims_arr.len() != 4 {
            bail!("manifest dims must have 4 entries");
        }
        let d: Vec<usize> = dims_arr.iter().filter_map(Json::as_usize).collect();
        let dims = LatticeDims::new(d[0], d[1], d[2], d[3])
            .map_err(|e| anyhow!("manifest dims: {e}"))?;

        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let file = dir.join(
                a.get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact {name} missing file"))?,
            );
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactSpec {
                name,
                file,
                inputs,
                outputs,
            });
        }

        Ok(Manifest {
            dims,
            cg_tol: j.get("cg_tol").and_then(Json::as_f64).unwrap_or(1e-10),
            cg_maxiter: j
                .get("cg_maxiter")
                .and_then(Json::as_usize)
                .unwrap_or(1000),
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Requires `make artifacts`; skipped when the artifacts are absent
    /// (offline build without the Python toolchain).
    #[test]
    fn loads_real_manifest() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            assert!(
                std::env::var_os("LQCD_REQUIRE_ARTIFACTS").is_none(),
                "LQCD_REQUIRE_ARTIFACTS set but artifacts/manifest.json missing"
            );
            eprintln!("skipping loads_real_manifest: artifacts/manifest.json missing");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifacts.len() >= 6);
        let meo = m.artifact("meo").unwrap();
        assert_eq!(meo.inputs.len(), 3, "u, psi, kappa");
        // u: (4, 2, T, Z, Y, XH, 3, 3, 2)
        assert_eq!(meo.inputs[0].shape.len(), 9);
        assert_eq!(meo.inputs[0].dtype, "f32");
        // psi: (T, Z, Y, XH, 4, 3, 2)
        assert_eq!(meo.inputs[1].shape.len(), 7);
        assert!(meo.file.exists());
        assert!(m.artifact("nonexistent").is_err());
    }
}
