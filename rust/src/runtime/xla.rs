//! Stub of the `xla` (xla_extension / PJRT) bindings.
//!
//! The offline container does not ship the XLA shared library, so this
//! module mirrors the subset of the `xla` crate API the executor uses and
//! fails at client construction. [`super::Runtime::load`] therefore
//! returns a clean "PJRT backend not available" error, every PJRT code
//! path degrades gracefully (the launcher falls back to the native
//! kernels), and the executor keeps compiling against the real call
//! shapes so swapping the genuine bindings back in is a one-line change
//! in `runtime/mod.rs`.

/// Error type of the stubbed bindings.
#[derive(Debug)]
pub struct XlaError(pub String);

type Result<T> = std::result::Result<T, XlaError>;

const UNAVAILABLE: &str =
    "PJRT backend not available: the xla_extension bindings are not bundled \
     in this build (native kernels remain fully functional)";

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError(UNAVAILABLE.to_string()))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError(UNAVAILABLE.to_string()))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(XlaError(UNAVAILABLE.to_string()))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError(UNAVAILABLE.to_string()))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError(UNAVAILABLE.to_string()))
    }
}

/// Host-side literal (stub).
pub struct Literal;

impl Literal {
    pub fn scalar(_v: f32) -> Literal {
        Literal
    }

    pub fn vec1(_v: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(XlaError(UNAVAILABLE.to_string()))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(XlaError(UNAVAILABLE.to_string()))
    }

    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        Err(XlaError(UNAVAILABLE.to_string()))
    }
}

/// Element types fetchable from a literal.
pub trait ElementType: Sized {}
impl ElementType for f32 {}
impl ElementType for i32 {}
impl ElementType for f64 {}
