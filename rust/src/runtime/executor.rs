//! PJRT executor: load HLO-text artifacts, compile once, execute from the
//! Rust hot path. Python never runs here — the artifacts were lowered at
//! build time by `python/compile/aot.py`.
//!
//! Interchange format is HLO *text* (see `/opt/xla-example/README.md` and
//! DESIGN.md): jax >= 0.5 serializes HloModuleProto with 64-bit ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.

use std::collections::HashMap;
use std::path::Path;

use crate::util::error::{anyhow, bail, Result};

use super::xla;
use crate::field::io::{fermion_to_canonical, gauge_to_canonical};
use crate::field::{FermionField, GaugeField};
use crate::lattice::Geometry;

use super::manifest::Manifest;

/// A PJRT CPU client with all manifest artifacts compiled.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load + compile every artifact in `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let mut executables = HashMap::new();
        for art in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                art.file
                    .to_str()
                    .ok_or_else(|| anyhow!("non-utf8 path {:?}", art.file))?,
            )
            .map_err(|e| anyhow!("parse {}: {e:?}", art.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", art.name))?;
            executables.insert(art.name.clone(), exe);
        }
        Ok(Runtime {
            manifest,
            client,
            executables,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute artifact `name` on raw f32 buffers (shape-checked against
    /// the manifest). Returns the flattened outputs.
    pub fn execute(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let spec = self.manifest.artifact(name)?;
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not compiled"))?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: {} inputs given, {} expected",
                inputs.len(),
                spec.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (buf, ispec)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if buf.len() != ispec.len() {
                bail!(
                    "{name} input {i}: {} elements given, {} expected",
                    buf.len(),
                    ispec.len()
                );
            }
            let lit = if ispec.shape.is_empty() {
                xla::Literal::scalar(buf[0])
            } else {
                let dims: Vec<i64> = ispec.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(buf)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("{name} input {i} reshape: {e:?}"))?
            };
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("{name} execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{name} fetch: {e:?}"))?;
        // aot.py lowers with return_tuple=True: outputs arrive as a tuple
        let outs = result
            .to_tuple()
            .map_err(|e| anyhow!("{name} untuple: {e:?}"))?;
        let mut out_bufs = Vec::with_capacity(outs.len());
        for (o, ospec) in outs.iter().zip(&spec.outputs) {
            let v: Vec<f32> = match ospec.dtype.as_str() {
                "f32" => o
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("{name} output: {e:?}"))?,
                "i32" => o
                    .to_vec::<i32>()
                    .map_err(|e| anyhow!("{name} output: {e:?}"))?
                    .into_iter()
                    .map(|x| x as f32)
                    .collect(),
                other => bail!("{name}: unsupported output dtype {other}"),
            };
            out_bufs.push(v);
        }
        Ok(out_bufs)
    }
}

/// The PJRT-backed even-odd preconditioned operator: executes the `meo`
/// artifact on the request path. Implements the same [`LinearOperator`]
/// interface as the native operators, so every solver runs on it.
pub struct PjrtMeo<'rt> {
    rt: &'rt Runtime,
    /// canonical gauge buffer, converted once
    u_canon: Vec<f32>,
    kappa: f32,
    half_volume: usize,
    artifact: &'static str,
}

impl<'rt> PjrtMeo<'rt> {
    pub fn new(rt: &'rt Runtime, geom: &Geometry, u: &GaugeField, kappa: f32) -> Result<Self> {
        if geom.local != rt.manifest.dims {
            bail!(
                "geometry {} != artifact lattice {}",
                geom.local,
                rt.manifest.dims
            );
        }
        Ok(PjrtMeo {
            rt,
            u_canon: gauge_to_canonical(u),
            kappa,
            half_volume: geom.local.half_volume(),
            artifact: "meo",
        })
    }

    /// Switch to the normal-operator artifact (`mdagm`).
    pub fn normal(mut self) -> Self {
        self.artifact = "mdagm";
        self
    }

    /// Run the whole-solver artifact (`cg_solve`): returns (x, iterations,
    /// rel |r|^2).
    pub fn cg_solve_artifact(
        &self,
        b: &FermionField,
    ) -> Result<(Vec<f32>, usize, f64)> {
        let psi = fermion_to_canonical(b);
        let outs = self.rt.execute(
            "cg_solve",
            &[self.u_canon.clone(), psi, vec![self.kappa]],
        )?;
        let x = outs[0].clone();
        let iters = outs[1][0] as usize;
        let rr = outs[2][0] as f64;
        Ok((x, iters, rr))
    }
}

impl crate::coordinator::operator::LinearOperator for PjrtMeo<'_> {
    fn apply(&mut self, out: &mut FermionField, psi: &FermionField) {
        let psi_canon = fermion_to_canonical(psi);
        let outs = self
            .rt
            .execute(
                self.artifact,
                &[self.u_canon.clone(), psi_canon, vec![self.kappa]],
            )
            .expect("PJRT execution failed");
        let canon: Vec<f64> = outs[0].iter().map(|&v| v as f64).collect();
        crate::field::io::fermion_from_canonical(out, &canon)
            .expect("PJRT output shape mismatch");
    }

    fn flops_per_apply(&self) -> u64 {
        let base = crate::dslash::flops::meo_flops(self.half_volume);
        if self.artifact == "mdagm" {
            2 * base
        } else {
            base
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::operator::{LinearOperator, NativeMeo};
    use crate::lattice::{LatticeDims, Tiling};
    use crate::util::rng::Rng;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// PJRT meo must equal the native meo on the same fields — the
    /// centerpiece cross-layer test (L1+L2 artifact vs L3 native kernel).
    #[test]
    fn pjrt_meo_matches_native() {
        let rt = match Runtime::load(&artifacts_dir()) {
            Ok(rt) => rt,
            Err(e) => {
                // LQCD_REQUIRE_ARTIFACTS marks an environment with the full
                // artifact + PJRT pipeline: there a load failure is a real
                // regression, not a missing optional dependency.
                assert!(
                    std::env::var_os("LQCD_REQUIRE_ARTIFACTS").is_none(),
                    "LQCD_REQUIRE_ARTIFACTS set but PJRT runtime failed to load: {e}"
                );
                eprintln!("skipping pjrt_meo_matches_native: {e}");
                return;
            }
        };
        let dims = rt.manifest.dims;
        let geom = Geometry::single_rank(dims, Tiling::new(2, 2).unwrap()).unwrap();
        let mut rng = Rng::seeded(42);
        let u = GaugeField::random(&geom, &mut rng);
        let psi = FermionField::gaussian(&geom, &mut rng);
        let kappa = 0.13f32;

        let mut pjrt = PjrtMeo::new(&rt, &geom, &u, kappa).unwrap();
        let mut out_pjrt = FermionField::zeros(&geom);
        pjrt.apply(&mut out_pjrt, &psi);

        let mut native = NativeMeo::new(&geom, u, kappa);
        let mut out_native = FermionField::zeros(&geom);
        native.apply(&mut out_native, &psi);

        let mut d = out_pjrt.clone();
        d.axpy(-1.0, &out_native);
        let rel = (d.norm2() / out_native.norm2()).sqrt();
        assert!(rel < 1e-5, "PJRT vs native rel diff {rel}");
    }
}
