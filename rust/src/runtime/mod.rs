//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` (L1 Pallas kernel + L2 JAX operator graphs)
//! and executes them from the Rust request path via the PJRT C API.

mod executor;
mod manifest;
pub mod xla;

pub use executor::{PjrtMeo, Runtime};
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
