//! Typed run configuration consumed by the launcher and examples.

use std::path::{Path, PathBuf};

use super::parser::{ConfigError, Document};
use crate::coordinator::Eo2Schedule;
use crate::dslash::Compression;
use crate::lattice::{GeometryError, LatticeDims, ProcGrid, Tiling};

#[derive(Clone, Debug)]
pub struct LatticeConfig {
    pub global: LatticeDims,
    pub grid: ProcGrid,
    pub tiling: Tiling,
    /// whether `lattice.tiling` was set explicitly (config key or CLI
    /// override). An explicit tiling pins the knob — the tune cache
    /// only fills it when this is false.
    pub tiling_explicit: bool,
}

#[derive(Clone, Debug)]
pub struct SolverConfig {
    pub kappa: f64,
    pub tol: f64,
    pub maxiter: usize,
    pub use_pjrt: bool,
    /// "cg" or "bicgstab"
    pub algorithm: String,
    /// Field/kernel precision: "f32" (paper hot path), "f64", or "mixed"
    /// (f64 outer iterative refinement around an f32 inner solve).
    pub precision: String,
    /// Mixed precision: relative tolerance of each inner f32 solve.
    pub inner_tol: f64,
    /// Mixed precision: cap on outer refinement steps.
    pub max_outer: usize,
    /// Worker-team threads the fused solver pipeline iterates on
    /// (1 = serial fused sweeps; residual histories are identical at
    /// any value). `None` (key unset) auto-derives a team size from
    /// the machine model ([`crate::perf::auto_solver_threads`]).
    pub threads: Option<usize>,
    /// Right-hand sides solved together per batched sweep (1 = the
    /// single-RHS fused pipeline; >1 routes through the multi-RHS
    /// block solver, streaming the gauge field once for all systems).
    pub nrhs: usize,
    /// Krylov restarts the solver health guard may perform after
    /// recoverable events (non-finite scalars, stagnation, residual
    /// drift) before declaring the solve failed.
    pub max_restarts: usize,
}

/// `[comm]`: hardening knobs of the simulated transport (distributed
/// solves only; single-rank runs never touch the wire).
#[derive(Clone, Debug)]
pub struct CommConfig {
    /// recv/collective deadline per message in ms; 0 waits forever
    pub timeout_ms: u64,
    /// retransmit attempts per corrupt/truncated/dropped halo message
    /// before the receiver reports a structured transport error
    pub max_retries: u32,
}

/// Gauge-link storage options.
#[derive(Clone, Debug)]
pub struct GaugeConfig {
    /// `gauge.compression`: `none` (18 reals/link, stream as stored) or
    /// `two-row` (12 reals/link, third row rebuilt in-register by the
    /// kernels — only valid for unitary links; see ARCHITECTURE.md).
    pub compression: Compression,
}

#[derive(Clone, Debug)]
pub struct ParallelConfig {
    /// OpenMP-analog threads per rank (paper: 12 per CMG)
    pub threads_per_rank: usize,
    /// force the comm path even for self-neighbor directions
    /// (the paper enforces x/y communication in its measurements)
    pub force_comm: bool,
    /// how the distributed EO2 merge partitions boundary sites across
    /// threads (`None` = let the tune cache / heuristic decide)
    pub eo2_schedule: Option<Eo2Schedule>,
    /// boundary-site granularity of the balanced EO2 partition
    /// (`None` = let the tune cache / heuristic decide)
    pub eo2_granularity: Option<usize>,
}

/// `[tune]`: autotuner cache location and sweep/assertion parameters.
#[derive(Clone, Debug)]
pub struct TuneConfig {
    /// where `lqcd tune` writes and `lqcd solve` looks for the
    /// per-machine cache
    pub cache_dir: PathBuf,
    /// total wall budget of one `lqcd tune` sweep
    pub budget_ms: u64,
    /// bench assertion floor: effective GB/s must reach this fraction
    /// of the fitted roofline
    pub roofline_floor: f64,
    /// `false` disables cache lookup on the solve path entirely
    /// (`--no-tune`): knobs come from CLI/config or the heuristics
    pub enabled: bool,
}

/// `[telemetry]`: span tracing, metrics export, and the automated
/// slowdown detector (see `perf::telemetry`). Disabled by default;
/// `lqcd solve --trace DIR` enables it for one run. Telemetry never
/// feeds back into the solver arithmetic: residual histories are
/// bitwise identical with it on or off.
#[derive(Clone, Debug)]
pub struct TelemetryConfig {
    /// master switch: record spans/metrics and write the exporters
    pub enabled: bool,
    /// output directory for `trace.json` / `metrics.json`
    /// (`None` = the run's artifacts dir)
    pub dir: Option<PathBuf>,
    /// per-thread span ring capacity; overflow is dropped and counted,
    /// never reallocated mid-solve
    pub buffer_spans: usize,
    /// trailing window of the slowdown detector's median/MAD estimate
    pub slowdown_window: usize,
    /// flag an iteration when its comm-wait/barrier time exceeds
    /// `median + k * MAD` over the trailing window...
    pub slowdown_k: f64,
    /// ...and exceeds `factor * median` (multiplicative guard)...
    pub slowdown_factor: f64,
    /// ...and exceeds this absolute floor in milliseconds (keeps noise
    /// on micro-iterations from tripping the detector)
    pub slowdown_min_ms: f64,
}

/// `[checkpoint]`: deterministic solver checkpoint/restart (see
/// `solver::checkpoint`). Disabled unless `dir` is set (or
/// `lqcd solve --checkpoint-dir DIR` is given). Checkpointing never
/// feeds back into the solver arithmetic: residual histories are
/// bitwise identical with it on or off, and a resumed run reproduces
/// the uninterrupted history bitwise from the checkpoint iteration on.
#[derive(Clone, Debug)]
pub struct CheckpointConfig {
    /// checkpoint directory (`None` = checkpointing off)
    pub dir: Option<PathBuf>,
    /// write a generation every N solver iterations (0 = never by
    /// iteration count)
    pub every_iters: u64,
    /// ...or every M wall-clock milliseconds (0 = never by clock;
    /// ignored on multi-rank runs, where clocks may diverge)
    pub every_ms: u64,
    /// committed generations to keep per rank (older ones rotate out)
    pub keep: usize,
    /// mirror each committed generation into the buddy rank's memory
    /// so a lost rank's state can be restored from its neighbor
    pub buddy: bool,
}

#[derive(Clone, Debug)]
pub struct RunConfig {
    pub lattice: LatticeConfig,
    pub solver: SolverConfig,
    pub gauge: GaugeConfig,
    pub parallel: ParallelConfig,
    pub tune: TuneConfig,
    pub comm: CommConfig,
    pub telemetry: TelemetryConfig,
    pub checkpoint: CheckpointConfig,
    /// `faults.spec`: fault-injection schedule for the simulated
    /// transport (see `comm::faults` for the grammar). Empty = no
    /// faults; parse-validated at load, applied by `lqcd solve`.
    pub faults: String,
    pub artifacts_dir: PathBuf,
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            lattice: LatticeConfig {
                global: LatticeDims::new(8, 8, 8, 16).unwrap(),
                grid: ProcGrid([1, 1, 1, 1]),
                tiling: Tiling::new(4, 4).unwrap(),
                tiling_explicit: false,
            },
            solver: SolverConfig {
                kappa: 0.13,
                tol: 1e-8,
                maxiter: 1000,
                use_pjrt: false,
                algorithm: "cg".into(),
                precision: "f32".into(),
                inner_tol: 1e-4,
                max_outer: 40,
                threads: None,
                nrhs: 1,
                max_restarts: 3,
            },
            gauge: GaugeConfig {
                compression: Compression::None,
            },
            parallel: ParallelConfig {
                threads_per_rank: 4,
                force_comm: false,
                eo2_schedule: None,
                eo2_granularity: None,
            },
            tune: TuneConfig {
                cache_dir: PathBuf::from("tune-cache"),
                budget_ms: 3000,
                roofline_floor: 0.5,
                enabled: true,
            },
            comm: CommConfig {
                timeout_ms: 30_000,
                max_retries: 3,
            },
            telemetry: TelemetryConfig {
                enabled: false,
                dir: None,
                buffer_spans: 65_536,
                slowdown_window: 8,
                slowdown_k: 6.0,
                slowdown_factor: 3.0,
                slowdown_min_ms: 2.0,
            },
            checkpoint: CheckpointConfig {
                dir: None,
                every_iters: 25,
                every_ms: 0,
                keep: 2,
                buddy: true,
            },
            faults: String::new(),
            artifacts_dir: PathBuf::from("artifacts"),
            seed: 20230227,
        }
    }
}

impl RunConfig {
    /// Validate the flag/key *combinations* of a `solve` run, up front
    /// and in one place (the per-key range checks live in parsing).
    /// Collects every offense so e.g.
    /// `--pjrt --precision f64 --gauge-compression two-row` reports all
    /// offending flags at once instead of whichever branch ran first.
    pub fn validate_solve(&self, use_pjrt: bool) -> Result<(), String> {
        let mut errs: Vec<String> = Vec::new();
        let s = &self.solver;
        if !matches!(s.algorithm.as_str(), "cg" | "bicgstab") {
            errs.push(format!(
                "solver.algorithm must be \"cg\" or \"bicgstab\" (got {:?})",
                s.algorithm
            ));
        }
        let nranks = self.lattice.grid.size();
        if use_pjrt {
            if matches!(s.precision.as_str(), "f64" | "mixed") {
                errs.push(format!(
                    "--pjrt only supports f32 (the artifacts are lowered at f32); \
                     got --precision {}",
                    s.precision
                ));
            }
            if s.nrhs > 1 {
                errs.push(
                    "--pjrt does not support --nrhs > 1 (native block solver only)"
                        .into(),
                );
            }
            if self.gauge.compression != Compression::None {
                errs.push(
                    "--pjrt does not support --gauge-compression (the artifacts \
                     stream full links)"
                        .into(),
                );
            }
            if nranks > 1 {
                errs.push(format!(
                    "--pjrt does not support a multi-rank grid (lattice.grid gives \
                     {nranks} ranks); drop --pjrt or use --grid 1x1x1x1"
                ));
            }
        }
        if s.nrhs > 1 && s.precision == "mixed" {
            errs.push(
                "--nrhs > 1 supports --precision f32 or f64; mixed-precision block \
                 refinement is an open ROADMAP item (PR 3/PR 4 notes), not a typo \
                 in your flags"
                    .into(),
            );
        }
        if nranks > 1 && s.precision == "mixed" {
            errs.push(
                "distributed solves (a multi-rank lattice.grid / --grid) support \
                 --precision f32 or f64; mixed refinement over the rank world is \
                 an open ROADMAP item"
                    .into(),
            );
        }
        if nranks > 1 && s.nrhs > crate::comm::MAX_WIRE_RHS {
            errs.push(format!(
                "distributed batched halos carry at most {} right-hand sides per \
                 message (the wire signature's mask width); got --nrhs {}",
                crate::comm::MAX_WIRE_RHS,
                s.nrhs
            ));
        }
        if !self.faults.is_empty() && nranks == 1 {
            errs.push(
                "fault injection (--inject-faults / faults.spec) targets the \
                 simulated transport: it needs a multi-rank grid (e.g. \
                 --grid 1x1x1x2)"
                    .into(),
            );
        }
        if errs.is_empty() {
            Ok(())
        } else {
            Err(errs.join("\n"))
        }
    }

    /// Load from a TOML-subset file; missing keys fall back to defaults.
    pub fn load(path: &Path) -> Result<RunConfig, ConfigError> {
        let doc = Document::load(path)?;
        RunConfig::from_document(&doc)
    }

    pub fn from_document(doc: &Document) -> Result<RunConfig, ConfigError> {
        let defaults = RunConfig::default();
        let geo_err = |e: GeometryError| ConfigError {
            line: 0,
            message: e.0,
        };

        let global = match doc.get("lattice.dims") {
            Some(v) => {
                let ints = v.as_ints().ok_or_else(|| ConfigError {
                    line: 0,
                    message: "lattice.dims must be an int array".into(),
                })?;
                if ints.len() != 4 {
                    return Err(ConfigError {
                        line: 0,
                        message: "lattice.dims must have 4 entries".into(),
                    });
                }
                LatticeDims::new(
                    ints[0] as usize,
                    ints[1] as usize,
                    ints[2] as usize,
                    ints[3] as usize,
                )
                .map_err(geo_err)?
            }
            None => defaults.lattice.global,
        };
        let grid = match doc.get("lattice.grid") {
            Some(v) => {
                let ints = v.as_ints().ok_or_else(|| ConfigError {
                    line: 0,
                    message: "lattice.grid must be an int array".into(),
                })?;
                if ints.len() != 4 {
                    return Err(ConfigError {
                        line: 0,
                        message: "lattice.grid must have 4 entries".into(),
                    });
                }
                ProcGrid([
                    ints[0] as usize,
                    ints[1] as usize,
                    ints[2] as usize,
                    ints[3] as usize,
                ])
            }
            None => defaults.lattice.grid,
        };
        let tiling_explicit = doc.get("lattice.tiling").is_some();
        let tiling = Tiling::parse(&doc.str_or("lattice.tiling", "4x4"))
            .map_err(|m| ConfigError { line: 0, message: m })?;

        Ok(RunConfig {
            lattice: LatticeConfig {
                global,
                grid,
                tiling,
                tiling_explicit,
            },
            solver: SolverConfig {
                kappa: doc.float_or("solver.kappa", defaults.solver.kappa),
                tol: doc.float_or("solver.tol", defaults.solver.tol),
                maxiter: doc.int_or("solver.maxiter", defaults.solver.maxiter as i64)
                    as usize,
                use_pjrt: doc.bool_or("solver.use_pjrt", defaults.solver.use_pjrt),
                algorithm: doc.str_or("solver.algorithm", &defaults.solver.algorithm),
                precision: {
                    let p = doc.str_or("solver.precision", &defaults.solver.precision);
                    match p.as_str() {
                        "f32" | "f64" | "mixed" => p,
                        other => {
                            return Err(ConfigError {
                                line: 0,
                                message: format!(
                                    "solver.precision must be f32, f64 or mixed (got {other:?})"
                                ),
                            })
                        }
                    }
                },
                inner_tol: {
                    let t = doc.float_or("solver.inner_tol", defaults.solver.inner_tol);
                    if !(t > 0.0 && t < 1.0) {
                        return Err(ConfigError {
                            line: 0,
                            message: format!(
                                "solver.inner_tol must be in (0, 1) (got {t})"
                            ),
                        });
                    }
                    t
                },
                max_outer: {
                    let n =
                        doc.int_or("solver.max_outer", defaults.solver.max_outer as i64);
                    if n <= 0 {
                        return Err(ConfigError {
                            line: 0,
                            message: format!("solver.max_outer must be positive (got {n})"),
                        });
                    }
                    n as usize
                },
                threads: match doc.get("solver.threads") {
                    None => defaults.solver.threads,
                    Some(_) => {
                        let n = doc.int_or("solver.threads", 0);
                        if n <= 0 {
                            return Err(ConfigError {
                                line: 0,
                                message: format!("solver.threads must be positive (got {n})"),
                            });
                        }
                        Some(n as usize)
                    }
                },
                nrhs: {
                    let n = doc.int_or("solver.nrhs", defaults.solver.nrhs as i64);
                    if n <= 0 {
                        return Err(ConfigError {
                            line: 0,
                            message: format!("solver.nrhs must be positive (got {n})"),
                        });
                    }
                    n as usize
                },
                max_restarts: {
                    let n = doc.int_or(
                        "solver.max_restarts",
                        defaults.solver.max_restarts as i64,
                    );
                    if n < 0 {
                        return Err(ConfigError {
                            line: 0,
                            message: format!(
                                "solver.max_restarts must be >= 0 (got {n})"
                            ),
                        });
                    }
                    n as usize
                },
            },
            gauge: GaugeConfig {
                compression: Compression::parse(
                    &doc.str_or("gauge.compression", defaults.gauge.compression.name()),
                )
                .map_err(|m| ConfigError { line: 0, message: m })?,
            },
            parallel: ParallelConfig {
                threads_per_rank: doc.int_or(
                    "parallel.threads_per_rank",
                    defaults.parallel.threads_per_rank as i64,
                ) as usize,
                force_comm: doc.bool_or("parallel.force_comm", defaults.parallel.force_comm),
                eo2_schedule: match doc.get("parallel.eo2_schedule") {
                    None => None,
                    Some(_) => Some(
                        Eo2Schedule::parse(&doc.str_or("parallel.eo2_schedule", ""))
                            .map_err(|m| ConfigError { line: 0, message: m })?,
                    ),
                },
                eo2_granularity: match doc.get("parallel.eo2_granularity") {
                    None => None,
                    Some(_) => {
                        let n = doc.int_or("parallel.eo2_granularity", 0);
                        if n <= 0 {
                            return Err(ConfigError {
                                line: 0,
                                message: format!(
                                    "parallel.eo2_granularity must be positive (got {n})"
                                ),
                            });
                        }
                        Some(n as usize)
                    }
                },
            },
            tune: TuneConfig {
                cache_dir: PathBuf::from(doc.str_or(
                    "tune.cache_dir",
                    &defaults.tune.cache_dir.to_string_lossy(),
                )),
                budget_ms: {
                    let n = doc.int_or("tune.budget_ms", defaults.tune.budget_ms as i64);
                    if n <= 0 {
                        return Err(ConfigError {
                            line: 0,
                            message: format!("tune.budget_ms must be positive (got {n})"),
                        });
                    }
                    n as u64
                },
                roofline_floor: {
                    let f =
                        doc.float_or("tune.roofline_floor", defaults.tune.roofline_floor);
                    if !(f > 0.0 && f <= 1.0) {
                        return Err(ConfigError {
                            line: 0,
                            message: format!(
                                "tune.roofline_floor must be in (0, 1] (got {f})"
                            ),
                        });
                    }
                    f
                },
                enabled: doc.bool_or("tune.enabled", defaults.tune.enabled),
            },
            comm: CommConfig {
                timeout_ms: {
                    let n = doc.int_or(
                        "comm.timeout_ms",
                        defaults.comm.timeout_ms as i64,
                    );
                    if n < 0 {
                        return Err(ConfigError {
                            line: 0,
                            message: format!(
                                "comm.timeout_ms must be >= 0 (0 = no deadline; got {n})"
                            ),
                        });
                    }
                    n as u64
                },
                max_retries: {
                    let n = doc.int_or(
                        "comm.max_retries",
                        defaults.comm.max_retries as i64,
                    );
                    if n < 0 {
                        return Err(ConfigError {
                            line: 0,
                            message: format!("comm.max_retries must be >= 0 (got {n})"),
                        });
                    }
                    n as u32
                },
            },
            telemetry: TelemetryConfig {
                enabled: doc.bool_or("telemetry.enabled", defaults.telemetry.enabled),
                dir: doc.get("telemetry.dir").map(|_| {
                    PathBuf::from(doc.str_or("telemetry.dir", ""))
                }),
                buffer_spans: {
                    let n = doc.int_or(
                        "telemetry.buffer_spans",
                        defaults.telemetry.buffer_spans as i64,
                    );
                    if n <= 0 {
                        return Err(ConfigError {
                            line: 0,
                            message: format!(
                                "telemetry.buffer_spans must be positive (got {n})"
                            ),
                        });
                    }
                    n as usize
                },
                slowdown_window: {
                    let n = doc.int_or(
                        "telemetry.slowdown_window",
                        defaults.telemetry.slowdown_window as i64,
                    );
                    if n < 2 {
                        return Err(ConfigError {
                            line: 0,
                            message: format!(
                                "telemetry.slowdown_window must be >= 2 (got {n})"
                            ),
                        });
                    }
                    n as usize
                },
                slowdown_k: {
                    let k = doc.float_or(
                        "telemetry.slowdown_k",
                        defaults.telemetry.slowdown_k,
                    );
                    if !(k > 0.0) {
                        return Err(ConfigError {
                            line: 0,
                            message: format!(
                                "telemetry.slowdown_k must be positive (got {k})"
                            ),
                        });
                    }
                    k
                },
                slowdown_factor: {
                    let f = doc.float_or(
                        "telemetry.slowdown_factor",
                        defaults.telemetry.slowdown_factor,
                    );
                    if !(f >= 1.0) {
                        return Err(ConfigError {
                            line: 0,
                            message: format!(
                                "telemetry.slowdown_factor must be >= 1 (got {f})"
                            ),
                        });
                    }
                    f
                },
                slowdown_min_ms: {
                    let m = doc.float_or(
                        "telemetry.slowdown_min_ms",
                        defaults.telemetry.slowdown_min_ms,
                    );
                    if !(m >= 0.0) {
                        return Err(ConfigError {
                            line: 0,
                            message: format!(
                                "telemetry.slowdown_min_ms must be >= 0 (got {m})"
                            ),
                        });
                    }
                    m
                },
            },
            checkpoint: CheckpointConfig {
                dir: doc.get("checkpoint.dir").map(|_| {
                    PathBuf::from(doc.str_or("checkpoint.dir", ""))
                }),
                every_iters: {
                    let n = doc.int_or(
                        "checkpoint.every_iters",
                        defaults.checkpoint.every_iters as i64,
                    );
                    if n < 0 {
                        return Err(ConfigError {
                            line: 0,
                            message: format!(
                                "checkpoint.every_iters must be >= 0 (0 = off; got {n})"
                            ),
                        });
                    }
                    n as u64
                },
                every_ms: {
                    let n = doc.int_or(
                        "checkpoint.every_ms",
                        defaults.checkpoint.every_ms as i64,
                    );
                    if n < 0 {
                        return Err(ConfigError {
                            line: 0,
                            message: format!(
                                "checkpoint.every_ms must be >= 0 (0 = off; got {n})"
                            ),
                        });
                    }
                    n as u64
                },
                keep: {
                    let n = doc.int_or(
                        "checkpoint.keep",
                        defaults.checkpoint.keep as i64,
                    );
                    if n < 1 {
                        return Err(ConfigError {
                            line: 0,
                            message: format!("checkpoint.keep must be >= 1 (got {n})"),
                        });
                    }
                    n as usize
                },
                buddy: doc.bool_or("checkpoint.buddy", defaults.checkpoint.buddy),
            },
            faults: {
                let spec = doc.str_or("faults.spec", "");
                // validate the schedule grammar at load so a typo fails
                // the run up front, not mid-solve
                if let Err(m) = crate::comm::FaultPlan::parse(&spec) {
                    return Err(ConfigError {
                        line: 0,
                        message: format!("faults.spec: {m}"),
                    });
                }
                spec
            },
            artifacts_dir: PathBuf::from(doc.str_or("artifacts_dir", "artifacts")),
            seed: doc.int_or("seed", defaults.seed as i64) as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = RunConfig::default();
        assert_eq!(c.lattice.global.volume(), 8 * 8 * 8 * 16);
        assert_eq!(c.solver.algorithm, "cg");
        assert_eq!(c.solver.precision, "f32");
        assert!(c.solver.inner_tol > 0.0 && c.solver.max_outer > 0);
        assert_eq!(c.solver.threads, None, "unset threads means auto");
        assert_eq!(c.solver.nrhs, 1);
        assert_eq!(c.gauge.compression, Compression::None);
    }

    #[test]
    fn gauge_compression_parses_and_validates() {
        let doc = Document::parse("[gauge]\ncompression = \"two-row\"").unwrap();
        let c = RunConfig::from_document(&doc).unwrap();
        assert_eq!(c.gauge.compression, Compression::TwoRow);
        let doc = Document::parse("[gauge]\ncompression = \"none\"").unwrap();
        let c = RunConfig::from_document(&doc).unwrap();
        assert_eq!(c.gauge.compression, Compression::None);
        let doc = Document::parse("[gauge]\ncompression = \"one-row\"").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "bad compression must fail");
    }

    #[test]
    fn precision_keys_parse_and_validate() {
        let doc = Document::parse(
            "[solver]\nprecision = \"mixed\"\ninner_tol = 1e-5\nmax_outer = 25\nthreads = 4",
        )
        .unwrap();
        let c = RunConfig::from_document(&doc).unwrap();
        assert_eq!(c.solver.precision, "mixed");
        assert!((c.solver.inner_tol - 1e-5).abs() < 1e-18);
        assert_eq!(c.solver.max_outer, 25);
        assert_eq!(c.solver.threads, Some(4));
        let doc = Document::parse("[solver]\nthreads = 0").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "zero threads must fail");

        let doc = Document::parse("[solver]\nnrhs = 4").unwrap();
        let c = RunConfig::from_document(&doc).unwrap();
        assert_eq!(c.solver.nrhs, 4);
        assert_eq!(c.solver.threads, None, "absent key stays auto");
        let doc = Document::parse("[solver]\nnrhs = 0").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "zero nrhs must fail");
        let doc = Document::parse("[solver]\nnrhs = -2").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "negative nrhs must fail");

        let doc = Document::parse("[solver]\nprecision = \"f16\"").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "bad precision must fail");
        let doc = Document::parse("[solver]\ninner_tol = -1.0").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "negative inner_tol must fail");
        let doc = Document::parse("[solver]\nmax_outer = -1").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "negative max_outer must fail");
    }

    #[test]
    fn full_document() {
        let doc = Document::parse(
            r#"
seed = 99
[lattice]
dims = [16, 16, 8, 8]
grid = [1, 1, 2, 2]
tiling = "8x2"
[solver]
kappa = 0.125
algorithm = "bicgstab"
[parallel]
threads_per_rank = 12
force_comm = true
"#,
        )
        .unwrap();
        let c = RunConfig::from_document(&doc).unwrap();
        assert_eq!(c.lattice.global, LatticeDims::new(16, 16, 8, 8).unwrap());
        assert_eq!(c.lattice.grid, ProcGrid([1, 1, 2, 2]));
        assert_eq!(c.lattice.tiling.to_string(), "8x2");
        assert_eq!(c.solver.algorithm, "bicgstab");
        assert_eq!(c.parallel.threads_per_rank, 12);
        assert!(c.parallel.force_comm);
        assert_eq!(c.seed, 99);
    }

    #[test]
    fn tune_and_eo2_keys_parse_and_validate() {
        let c = RunConfig::default();
        assert!(!c.lattice.tiling_explicit, "default tiling is not pinned");
        assert_eq!(c.parallel.eo2_schedule, None);
        assert_eq!(c.parallel.eo2_granularity, None);
        assert!(c.tune.enabled);

        let doc = Document::parse(
            "[lattice]\ntiling = \"4x4\"\n\
             [parallel]\neo2_schedule = \"balanced\"\neo2_granularity = 8\n\
             [tune]\ncache_dir = \"/tmp/tc\"\nbudget_ms = 500\n\
             roofline_floor = 0.25\nenabled = false",
        )
        .unwrap();
        let c = RunConfig::from_document(&doc).unwrap();
        assert!(c.lattice.tiling_explicit, "present key pins the tiling");
        assert_eq!(c.parallel.eo2_schedule, Some(Eo2Schedule::Balanced));
        assert_eq!(c.parallel.eo2_granularity, Some(8));
        assert_eq!(c.tune.cache_dir, PathBuf::from("/tmp/tc"));
        assert_eq!(c.tune.budget_ms, 500);
        assert!((c.tune.roofline_floor - 0.25).abs() < 1e-15);
        assert!(!c.tune.enabled);

        let doc = Document::parse("[parallel]\neo2_schedule = \"striped\"").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "bad schedule must fail");
        let doc = Document::parse("[parallel]\neo2_granularity = 0").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "zero granularity must fail");
        let doc = Document::parse("[tune]\nbudget_ms = 0").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "zero budget must fail");
        let doc = Document::parse("[tune]\nroofline_floor = 1.5").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "floor > 1 must fail");
    }

    #[test]
    fn comm_and_fault_keys_parse_and_validate() {
        let c = RunConfig::default();
        assert_eq!(c.comm.timeout_ms, 30_000);
        assert_eq!(c.comm.max_retries, 3);
        assert_eq!(c.solver.max_restarts, 3);
        assert!(c.faults.is_empty());

        let doc = Document::parse(
            "[comm]\ntimeout_ms = 250\nmax_retries = 5\n\
             [solver]\nmax_restarts = 1\n\
             [faults]\nspec = \"drop:seed=7\"",
        )
        .unwrap();
        let c = RunConfig::from_document(&doc).unwrap();
        assert_eq!(c.comm.timeout_ms, 250);
        assert_eq!(c.comm.max_retries, 5);
        assert_eq!(c.solver.max_restarts, 1);
        assert_eq!(c.faults, "drop:seed=7");

        // timeout 0 = wait forever is legal; negatives are not
        let doc = Document::parse("[comm]\ntimeout_ms = 0").unwrap();
        assert_eq!(RunConfig::from_document(&doc).unwrap().comm.timeout_ms, 0);
        let doc = Document::parse("[comm]\ntimeout_ms = -1").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "negative timeout must fail");
        let doc = Document::parse("[comm]\nmax_retries = -1").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "negative retries must fail");
        let doc = Document::parse("[solver]\nmax_restarts = -1").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "negative restarts must fail");

        // a bad schedule grammar fails at load, not mid-solve
        let doc = Document::parse("[faults]\nspec = \"explode:seed=7\"").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "unknown fault must fail");

        // fault injection needs a wire to inject into
        let doc = Document::parse("[faults]\nspec = \"drop:seed=7\"").unwrap();
        let c = RunConfig::from_document(&doc).unwrap();
        let err = c.validate_solve(false).expect_err("faults on 1 rank");
        assert!(err.contains("multi-rank"), "{err}");
        let doc = Document::parse(
            "[lattice]\ngrid = [1, 1, 1, 2]\n[faults]\nspec = \"drop:seed=7\"",
        )
        .unwrap();
        let c = RunConfig::from_document(&doc).unwrap();
        assert!(c.validate_solve(false).is_ok());
    }

    #[test]
    fn telemetry_keys_parse_and_validate() {
        let c = RunConfig::default();
        assert!(!c.telemetry.enabled, "telemetry is off by default");
        assert_eq!(c.telemetry.dir, None);
        assert_eq!(c.telemetry.buffer_spans, 65_536);
        assert_eq!(c.telemetry.slowdown_window, 8);
        assert!((c.telemetry.slowdown_k - 6.0).abs() < 1e-15);
        assert!((c.telemetry.slowdown_factor - 3.0).abs() < 1e-15);
        assert!((c.telemetry.slowdown_min_ms - 2.0).abs() < 1e-15);

        let doc = Document::parse(
            "[telemetry]\nenabled = true\ndir = \"traces\"\nbuffer_spans = 1024\n\
             slowdown_window = 16\nslowdown_k = 4.0\nslowdown_factor = 2.5\n\
             slowdown_min_ms = 0.5",
        )
        .unwrap();
        let c = RunConfig::from_document(&doc).unwrap();
        assert!(c.telemetry.enabled);
        assert_eq!(c.telemetry.dir, Some(PathBuf::from("traces")));
        assert_eq!(c.telemetry.buffer_spans, 1024);
        assert_eq!(c.telemetry.slowdown_window, 16);
        assert!((c.telemetry.slowdown_k - 4.0).abs() < 1e-15);
        assert!((c.telemetry.slowdown_factor - 2.5).abs() < 1e-15);
        assert!((c.telemetry.slowdown_min_ms - 0.5).abs() < 1e-15);

        let doc = Document::parse("[telemetry]\nbuffer_spans = 0").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "zero ring must fail");
        let doc = Document::parse("[telemetry]\nslowdown_window = 1").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "window < 2 must fail");
        let doc = Document::parse("[telemetry]\nslowdown_k = 0.0").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "k = 0 must fail");
        let doc = Document::parse("[telemetry]\nslowdown_factor = 0.5").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "factor < 1 must fail");
        let doc = Document::parse("[telemetry]\nslowdown_min_ms = -1.0").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "negative floor must fail");
    }

    #[test]
    fn checkpoint_keys_parse_and_validate() {
        let c = RunConfig::default();
        assert_eq!(c.checkpoint.dir, None, "checkpointing is off by default");
        assert_eq!(c.checkpoint.every_iters, 25);
        assert_eq!(c.checkpoint.every_ms, 0);
        assert_eq!(c.checkpoint.keep, 2);
        assert!(c.checkpoint.buddy);

        let doc = Document::parse(
            "[checkpoint]\ndir = \"ckpt\"\nevery_iters = 10\nevery_ms = 5000\n\
             keep = 3\nbuddy = false",
        )
        .unwrap();
        let c = RunConfig::from_document(&doc).unwrap();
        assert_eq!(c.checkpoint.dir, Some(PathBuf::from("ckpt")));
        assert_eq!(c.checkpoint.every_iters, 10);
        assert_eq!(c.checkpoint.every_ms, 5000);
        assert_eq!(c.checkpoint.keep, 3);
        assert!(!c.checkpoint.buddy);

        let doc = Document::parse("[checkpoint]\nevery_iters = -1").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "negative cadence must fail");
        let doc = Document::parse("[checkpoint]\nevery_ms = -1").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "negative clock must fail");
        let doc = Document::parse("[checkpoint]\nkeep = 0").unwrap();
        assert!(RunConfig::from_document(&doc).is_err(), "keep = 0 must fail");
    }

    #[test]
    fn bad_dims_rejected() {
        let doc = Document::parse("[lattice]\ndims = [15, 4, 4, 4]").unwrap();
        assert!(RunConfig::from_document(&doc).is_err());
        let doc = Document::parse("[lattice]\ndims = [4, 4, 4]").unwrap();
        assert!(RunConfig::from_document(&doc).is_err());
    }
}
