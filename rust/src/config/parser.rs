//! TOML-subset parser. See module docs in `config/mod.rs` for the grammar.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug)]
pub struct ConfigError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ConfigError {}

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_ints(&self) -> Option<Vec<i64>> {
        match self {
            Value::Array(items) => items.iter().map(Value::as_int).collect(),
            _ => None,
        }
    }
}

/// A parsed document: dotted-path keys (`section.key`) to values.
#[derive(Debug, Default, Clone)]
pub struct Document {
    entries: BTreeMap<String, Value>,
}

impl Document {
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut doc = Document::default();
        let mut section = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(head) = line.strip_prefix('[') {
                let head = head.strip_suffix(']').ok_or_else(|| ConfigError {
                    line: lineno,
                    message: "unterminated section header".into(),
                })?;
                section = head.trim().to_string();
                if section.is_empty() {
                    return Err(ConfigError {
                        line: lineno,
                        message: "empty section name".into(),
                    });
                }
                continue;
            }
            let (key, val) = line.split_once('=').ok_or_else(|| ConfigError {
                line: lineno,
                message: format!("expected key = value, got {line:?}"),
            })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(ConfigError {
                    line: lineno,
                    message: "empty key".into(),
                });
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let value = parse_value(val.trim(), lineno)?;
            if doc.entries.insert(full.clone(), value).is_some() {
                return Err(ConfigError {
                    line: lineno,
                    message: format!("duplicate key {full}"),
                });
            }
        }
        Ok(doc)
    }

    pub fn load(path: &std::path::Path) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path).map_err(|e| ConfigError {
            line: 0,
            message: format!("{}: {e}", path.display()),
        })?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn set(&mut self, key: &str, value: Value) {
        self.entries.insert(key.to_string(), value);
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string does not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, line: usize) -> Result<Value, ConfigError> {
    let err = |m: String| ConfigError { line, message: m };
    if s.is_empty() {
        return Err(err("missing value".into()));
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| err("unterminated string".into()))?;
        return Ok(Value::Str(body.to_string()));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| err("unterminated array".into()))?;
        let body = body.trim();
        if body.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items: Result<Vec<Value>, ConfigError> = body
            .split(',')
            .map(|item| parse_value(item.trim(), line))
            .collect();
        return Ok(Value::Array(items?));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(format!("cannot parse value {s:?}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# run configuration
title = "weak scaling"     # inline comment

[lattice]
dims = [16, 16, 8, 8]
tiling = "4x4"

[solver]
kappa = 0.13
tol = 1e-8
maxiter = 500
use_pjrt = true
"#;

    #[test]
    fn parses_sample() {
        let doc = Document::parse(SAMPLE).unwrap();
        assert_eq!(doc.str_or("title", ""), "weak scaling");
        assert_eq!(
            doc.get("lattice.dims").unwrap().as_ints().unwrap(),
            vec![16, 16, 8, 8]
        );
        assert_eq!(doc.str_or("lattice.tiling", ""), "4x4");
        assert!((doc.float_or("solver.kappa", 0.0) - 0.13).abs() < 1e-12);
        assert!((doc.float_or("solver.tol", 0.0) - 1e-8).abs() < 1e-20);
        assert_eq!(doc.int_or("solver.maxiter", 0), 500);
        assert!(doc.bool_or("solver.use_pjrt", false));
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = Document::parse("x = 3").unwrap();
        assert_eq!(doc.float_or("x", 0.0), 3.0);
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(Document::parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn bad_syntax_reports_line() {
        let e = Document::parse("ok = 1\nbogus line").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn unterminated_string_rejected() {
        assert!(Document::parse("s = \"abc").is_err());
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = Document::parse("s = \"a#b\"").unwrap();
        assert_eq!(doc.str_or("s", ""), "a#b");
    }

    #[test]
    fn empty_array() {
        let doc = Document::parse("a = []").unwrap();
        assert_eq!(doc.get("a").unwrap(), &Value::Array(vec![]));
    }

    #[test]
    fn defaults_for_missing_keys() {
        let doc = Document::parse("").unwrap();
        assert_eq!(doc.int_or("nope", 7), 7);
        assert_eq!(doc.str_or("nope", "d"), "d");
    }
}
