//! Run configuration: a TOML-subset parser plus the typed [`RunConfig`]
//! consumed by the launcher. (`serde`/`toml` are unavailable offline, so
//! the parser is a substrate of this repo.)
//!
//! Supported syntax: `[section.subsection]` headers, `key = value` with
//! string / integer / float / boolean / homogeneous-array values, `#`
//! comments, blank lines.

mod parser;
mod run;

pub use parser::{ConfigError, Document, Value};
pub use run::{
    CheckpointConfig, GaugeConfig, LatticeConfig, ParallelConfig, RunConfig,
    SolverConfig, TelemetryConfig, TuneConfig,
};
