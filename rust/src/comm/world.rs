//! Simulated MPI: an in-process rank world.
//!
//! Each rank is an OS thread; point-to-point messages travel over
//! channels. The API mirrors the MPI subset the paper's code needs:
//! tagged send/recv, barrier, and an all-reduce (for solver dot
//! products). Communication is FUNNELED as on Fugaku (§3.6): only the
//! rank's master thread calls these functions.
//!
//! # Fault tolerance
//!
//! The transport is hardened against the failure modes the fault plan
//! ([`crate::comm::faults`]) can inject — and, more importantly, against
//! the real-world analogues they model:
//!
//! * every payload travels under a wire header carrying a **sequence
//!   number** (per `(sender, tag)` stream) and an FNV-1a **checksum**
//!   over the payload bits and length;
//! * every `recv` has a **deadline** (`WorldOpts::timeout_ms`; 0 = wait
//!   forever) and returns a structured [`CommError`] instead of blocking
//!   the world on a lost message;
//! * a corrupt / truncated message, or a deadline expiry, triggers a
//!   bounded **retransmit** ([`WorldOpts::max_retries`], exponential
//!   backoff accounted in simulated time) from the sender-side pristine
//!   store — the in-process model of a NIC retransmit window. The store
//!   is only armed when a fault plan is active: without injection the
//!   in-process channel cannot lose or corrupt bytes, so the fault-free
//!   hot path pays no payload copies;
//! * a stale sequence number (duplicate delivery) is dropped silently;
//! * once a communicator fails it is **poisoned**: every later comm call
//!   short-circuits with the original error instead of stacking one
//!   timeout per call, so a dead peer costs each survivor at most one
//!   deadline per blocking primitive in flight.
//!
//! All recovery actions are counted in [`CommStats`], which the solver
//! health guard surfaces in `SolveStats`.

use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::faults::{FaultPlan, FaultState, IterAction, MessageAction};
use crate::perf::telemetry::{
    Tracer, EV_CORRUPT, EV_DELAY, EV_DUPLICATE, EV_RETRANSMIT, EV_SEND, EV_TIMEOUT,
    EV_ZEROFILL,
};

/// A wire buffer: halo payloads travel at the precision of the field
/// they were packed from (12 reals per site either way).
#[derive(Clone, Debug)]
pub enum Payload {
    F32(Vec<f32>),
    F64(Vec<f64>),
}

/// A structured communication-layer error: what went wrong, on which
/// rank, and which message (peer, tag, sequence) was involved.
#[derive(Clone, Debug)]
pub enum CommError {
    /// A `recv` deadline expired with no matching message and no
    /// retransmittable copy in the sender store.
    Timeout { rank: usize, peer: usize, tag: u64, elapsed_ms: u64 },
    /// A barrier/reduction deadline expired: some rank never arrived.
    CollectiveTimeout { rank: usize, elapsed_ms: u64 },
    /// Checksum mismatch that retransmission could not heal.
    Corrupt { rank: usize, peer: usize, tag: u64, seq: u64, retries: u32 },
    /// The payload's precision did not match the `recv`'s type (a type
    /// confusion, never a silent cast).
    PrecisionMismatch {
        rank: usize,
        peer: usize,
        tag: u64,
        wanted: &'static str,
        got: &'static str,
    },
    /// Fault injection killed this rank at a solver iteration.
    Killed { rank: usize, iteration: usize },
    /// A protocol-level disagreement surfaced *before* any payload is
    /// posted (see [`validate_wire_format`]).
    Protocol(String),
}

impl fmt::Display for CommError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CommError::Timeout { rank, peer, tag, elapsed_ms } => write!(
                f,
                "recv timeout on rank {rank}: no message from rank {peer} with \
                 tag {tag} within {elapsed_ms} ms (and no retransmittable copy)"
            ),
            CommError::CollectiveTimeout { rank, elapsed_ms } => write!(
                f,
                "collective timeout on rank {rank}: a peer failed to reach the \
                 barrier within {elapsed_ms} ms"
            ),
            CommError::Corrupt { rank, peer, tag, seq, retries } => write!(
                f,
                "corrupt message on rank {rank}: checksum mismatch from rank \
                 {peer}, tag {tag}, seq {seq}; unrecovered after {retries} \
                 retransmit attempts"
            ),
            CommError::PrecisionMismatch { rank, peer, tag, wanted, got } => write!(
                f,
                "recv precision mismatch: wanted {wanted}, got {got} (rank \
                 {rank}, from rank {peer}, tag {tag})"
            ),
            CommError::Killed { rank, iteration } => write!(
                f,
                "rank {rank} killed by fault injection at solver iteration \
                 {iteration}"
            ),
            CommError::Protocol(msg) => f.write_str(msg),
        }
    }
}

impl std::error::Error for CommError {}

/// Recovery/diagnostic counters of one communicator. Snapshot with
/// [`Comm::stats`]; the solver health guard folds them into
/// `SolveStats`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CommStats {
    /// messages healed from the sender-side retransmit store
    pub retransmits: u64,
    /// recv/collective deadlines that expired (including recovered ones)
    pub timeouts: u64,
    /// stale-sequence (duplicate) deliveries dropped
    pub duplicates_dropped: u64,
    /// checksum/length mismatches detected on arrival
    pub corrupt_detected: u64,
    /// sends the fault plan delayed
    pub delayed: u64,
    /// faults this rank's plan injected (as the acting side)
    pub injected: u64,
    /// simulated exponential-backoff milliseconds accounted (not slept)
    /// while waiting on retransmissions
    pub backoff_ms: u64,
    /// halo buffers `recv_or_zero` had to zero-fill after a failed recv
    /// — every one of these means a sweep ran on fabricated data, so
    /// the count is surfaced through `SolveStats`/`BlockSolveStats`
    pub zero_fills: u64,
}

/// Scalars that can travel through the simulated-MPI world. Implemented
/// for `f32` and `f64`; a `recv` with the wrong precision for the
/// matching send surfaces [`CommError::PrecisionMismatch`]. The
/// [`validate_wire_format`] handshake exists to catch that confusion
/// *before* the first send of a batched exchange.
pub trait CommScalar: Copy + Send + 'static {
    /// Wire identifier of this scalar (part of the halo wire signature).
    const WIRE_ID: u64;
    /// Human name used when decoding a wire-signature mismatch.
    const WIRE_NAME: &'static str;
    /// Zero fill used when a faulted recv must still produce a buffer.
    const ZERO: Self;

    fn wrap(v: Vec<Self>) -> Payload;
    /// Unwrap a payload of this precision; `Err` carries the name of the
    /// precision actually found.
    fn try_unwrap(p: Payload) -> Result<Vec<Self>, &'static str>;
}

impl CommScalar for f32 {
    const WIRE_ID: u64 = 1;
    const WIRE_NAME: &'static str = "f32";
    const ZERO: f32 = 0.0;

    fn wrap(v: Vec<f32>) -> Payload {
        Payload::F32(v)
    }

    fn try_unwrap(p: Payload) -> Result<Vec<f32>, &'static str> {
        match p {
            Payload::F32(v) => Ok(v),
            Payload::F64(_) => Err(f64::WIRE_NAME),
        }
    }
}

impl CommScalar for f64 {
    const WIRE_ID: u64 = 2;
    const WIRE_NAME: &'static str = "f64";
    const ZERO: f64 = 0.0;

    fn wrap(v: Vec<f64>) -> Payload {
        Payload::F64(v)
    }

    fn try_unwrap(p: Payload) -> Result<Vec<f64>, &'static str> {
        match p {
            Payload::F64(v) => Ok(v),
            Payload::F32(_) => Err(f32::WIRE_NAME),
        }
    }
}

/// Most right-hand sides a batched halo message can describe: the active
/// mask must fit the wire signature's 32 mask bits.
pub const MAX_WIRE_RHS: usize = 32;

/// Sentinel signature a rank posts when its own batch is unencodable
/// (`nrhs > MAX_WIRE_RHS`): it still joins the collective — so no rank
/// hangs at the barrier — and can never equal a valid signature
/// (precision nibble 0xF).
const OVERFLOW_SIG: u64 = 0xF << 44;

/// Encode the halo wire format — (precision, nrhs, active mask) — into
/// one u64 so every rank can compare formats in a single collective and
/// batched message tags can carry the format they were packed under.
/// Bits: `[0, 32)` active mask, `[32, 44)` nrhs, `[44, 48)` precision id.
///
/// Panics when `nrhs > MAX_WIRE_RHS`; the batched exchange only calls
/// this after [`validate_wire_format`] succeeded (which reports the
/// overflow as a structured error instead), so the assert is a
/// defense-in-depth invariant, not a reachable failure mode.
pub fn wire_sig<S: CommScalar>(nrhs: usize, active: &[bool]) -> u64 {
    assert!(
        nrhs <= MAX_WIRE_RHS,
        "batched halos support at most {MAX_WIRE_RHS} RHS per message (got {nrhs})"
    );
    debug_assert_eq!(active.len(), nrhs);
    let mut mask = 0u64;
    for (r, &on) in active.iter().enumerate() {
        if on {
            mask |= 1 << r;
        }
    }
    mask | ((nrhs as u64) << 32) | (S::WIRE_ID << 44)
}

/// Decode a wire signature for error reporting.
pub fn decode_wire_sig(sig: u64) -> String {
    let mask = sig & 0xffff_ffff;
    let nrhs = ((sig >> 32) & 0xfff) as usize;
    let prec = match sig >> 44 {
        1 => "f32",
        2 => "f64",
        _ => "?",
    };
    let mask_str: String = (0..nrhs.min(MAX_WIRE_RHS))
        .map(|r| if mask & (1 << r) != 0 { '1' } else { '0' })
        .collect();
    format!("precision {prec}, nrhs {nrhs}, active mask [{mask_str}]")
}

/// Wire-format handshake: every rank posts its (precision, nrhs, active
/// mask) signature and compares against the whole world. Run BEFORE the
/// first halo send of a batched exchange, so a rank-count, precision or
/// mask desync surfaces as one structured [`CommError`] naming the
/// disagreeing ranks — instead of a type panic (or a tag-mismatch hang)
/// in the middle of the exchange.
pub fn validate_wire_format<S: CommScalar>(
    comm: &Comm,
    nrhs: usize,
    active: &[bool],
) -> Result<(), CommError> {
    // an unencodable batch still joins the collective (sentinel sig) so
    // the other ranks are never left hanging at the barrier, then
    // reports the overflow as a structured error on every rank
    let sig = if nrhs <= MAX_WIRE_RHS {
        wire_sig::<S>(nrhs, active)
    } else {
        OVERFLOW_SIG
    };
    let sigs = comm.exchange_sigs(sig);
    if nrhs > MAX_WIRE_RHS {
        return Err(CommError::Protocol(format!(
            "batched halos carry at most {MAX_WIRE_RHS} right-hand sides per \
             message (the wire signature's mask width); got nrhs {nrhs}"
        )));
    }
    if sigs.iter().all(|&s| s == sig) {
        return Ok(());
    }
    let lines: Vec<String> = sigs
        .iter()
        .enumerate()
        .map(|(r, &s)| format!("  rank {r}: {}", decode_wire_sig(s)))
        .collect();
    Err(CommError::Protocol(format!(
        "halo wire-format mismatch across the rank world (detected before any \
         payload was sent):\n{}",
        lines.join("\n")
    )))
}

/// FNV-1a over the payload's bit patterns and length: cheap, and any
/// truncation or bit flip moves it. Not cryptographic — it models the
/// link-level CRC of a real interconnect.
fn payload_bytes(p: &Payload) -> u64 {
    match p {
        Payload::F32(v) => (v.len() * 4) as u64,
        Payload::F64(v) => (v.len() * 8) as u64,
    }
}

fn payload_checksum(p: &Payload) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut eat = |w: u64| {
        h ^= w;
        h = h.wrapping_mul(PRIME);
    };
    match p {
        Payload::F32(v) => {
            eat(v.len() as u64 | (1 << 60));
            for x in v {
                eat(u64::from(x.to_bits()));
            }
        }
        Payload::F64(v) => {
            eat(v.len() as u64 | (2 << 60));
            for x in v {
                eat(x.to_bits());
            }
        }
    }
    h
}

/// Flip payload bits without touching its length (checksum computed
/// before the flip stays pristine, so the receiver detects it).
fn flip_bits(p: Payload) -> Payload {
    match p {
        Payload::F32(mut v) => {
            if let Some(x) = v.first_mut() {
                *x = f32::from_bits(x.to_bits() ^ 0x5A5A_5A5A);
            }
            Payload::F32(v)
        }
        Payload::F64(mut v) => {
            if let Some(x) = v.first_mut() {
                *x = f64::from_bits(x.to_bits() ^ 0x5A5A_5A5A_5A5A_5A5A);
            }
            Payload::F64(v)
        }
    }
}

/// Silent data corruption: poison one element with NaN and let the
/// sender recompute the checksum, so the transport validates it and only
/// the solver health guard can catch the damage.
fn poison_nan(p: Payload) -> Payload {
    match p {
        Payload::F32(mut v) => {
            let mid = v.len() / 2;
            if let Some(x) = v.get_mut(mid) {
                *x = f32::NAN;
            }
            Payload::F32(v)
        }
        Payload::F64(mut v) => {
            let mid = v.len() / 2;
            if let Some(x) = v.get_mut(mid) {
                *x = f64::NAN;
            }
            Payload::F64(v)
        }
    }
}

/// Halve the payload (checksum of the full payload stays on the header,
/// so the length mismatch is detected on arrival).
fn truncate_half(p: Payload) -> Payload {
    match p {
        Payload::F32(mut v) => {
            let n = v.len() / 2;
            v.truncate(n);
            Payload::F32(v)
        }
        Payload::F64(mut v) => {
            let n = v.len() / 2;
            v.truncate(n);
            Payload::F64(v)
        }
    }
}

/// A tagged message under the wire header (sequence + checksum).
struct Msg {
    from: usize,
    tag: u64,
    seq: u64,
    checksum: u64,
    payload: Payload,
}

/// A barrier whose `wait` can give up after a deadline. A timed-out
/// waiter *withdraws* its arrival count so it cannot corrupt a later
/// generation; `timeout_ms == 0` waits forever (plain barrier).
struct TimedBarrier {
    n: usize,
    /// (arrived count, generation)
    state: Mutex<(usize, u64)>,
    cv: Condvar,
}

impl TimedBarrier {
    fn new(n: usize) -> TimedBarrier {
        TimedBarrier { n, state: Mutex::new((0, 0)), cv: Condvar::new() }
    }

    /// Returns `false` on deadline expiry (the barrier did not complete
    /// for this waiter).
    fn wait(&self, timeout_ms: u64) -> bool {
        let mut st = self.state.lock().unwrap();
        let gen = st.1;
        st.0 += 1;
        if st.0 == self.n {
            st.0 = 0;
            st.1 += 1;
            self.cv.notify_all();
            return true;
        }
        if timeout_ms == 0 {
            while st.1 == gen {
                st = self.cv.wait(st).unwrap();
            }
            return true;
        }
        let deadline = Instant::now() + Duration::from_millis(timeout_ms);
        while st.1 == gen {
            let now = Instant::now();
            if now >= deadline {
                st.0 -= 1; // withdraw: don't poison the next generation
                return false;
            }
            st = self.cv.wait_timeout(st, deadline - now).unwrap().0;
        }
        true
    }
}

/// Sender-side pristine-copy store keyed by (from, to, tag, seq): the
/// in-process model of a NIC retransmit window. Only armed when a fault
/// plan is active.
type RetransmitStore = Arc<Mutex<HashMap<(usize, usize, u64, u64), Payload>>>;

/// Per-rank communicator handle.
pub struct Comm {
    pub rank: usize,
    pub nranks: usize,
    senders: Vec<Sender<Msg>>,
    inbox: Receiver<Msg>,
    /// messages that arrived while waiting for a different (from, tag)
    pending: HashMap<(usize, u64), Vec<Msg>>,
    /// recv/collective deadline in ms; 0 = wait forever
    timeout_ms: u64,
    /// bounded retransmit attempts per corrupt message
    max_retries: u32,
    plan: Arc<FaultPlan>,
    fstate: RefCell<FaultState>,
    /// next sequence number per outgoing (to, tag) stream
    seq_send: RefCell<HashMap<(usize, u64), u64>>,
    /// next expected sequence number per incoming (from, tag) stream
    seq_recv: HashMap<(usize, u64), u64>,
    store: Option<RetransmitStore>,
    stats: RefCell<CommStats>,
    /// poison slot: once a comm call fails, every later call
    /// short-circuits with this error instead of stacking deadlines
    fault: RefCell<Option<CommError>>,
    barrier: Arc<TimedBarrier>,
    reduce_slots: Arc<Mutex<Vec<f64>>>,
    reduce_barrier: Arc<TimedBarrier>,
    /// wire-signature slots for the pre-exchange format handshake
    sig_slots: Arc<Mutex<Vec<u64>>>,
    /// per-rank vector slots for `allgather_f64`
    gather_slots: Arc<Mutex<Vec<Vec<f64>>>>,
    /// barrier shared by the sig/gather collectives (all collective calls
    /// are made in identical order on every rank, so one barrier serves)
    coll_barrier: Arc<TimedBarrier>,
    /// span tracer for transport events; `None` keeps the hot path free
    /// of telemetry branches beyond one pointer test per event site
    tracer: Option<Arc<Tracer>>,
}

impl Comm {
    /// Attach a span tracer. Transport events (sends, retransmits,
    /// timeouts, injected delays) are recorded on ring 0: comms are
    /// FUNNELED, so the rank master thread — which runs as team tid 0 —
    /// is the only caller and the single-writer-per-ring invariant holds.
    pub fn set_tracer(&mut self, tracer: Arc<Tracer>) {
        self.tracer = Some(tracer);
    }

    fn ev(&self, code: u8, bytes: u64) {
        if let Some(t) = &self.tracer {
            t.event(0, code, bytes);
        }
    }

    /// Non-blocking send (buffered by the channel). The payload travels
    /// under a (sequence, checksum) wire header; when a fault plan is
    /// active a pristine copy enters the retransmit store first and the
    /// plan decides the payload's fate on the wire.
    pub fn send<S: CommScalar>(&self, to: usize, tag: u64, payload: Vec<S>) {
        let seq = {
            let mut m = self.seq_send.borrow_mut();
            let c = m.entry((to, tag)).or_insert(0);
            let s = *c;
            *c += 1;
            s
        };
        let p = S::wrap(payload);
        let action =
            self.plan.message_action(&mut self.fstate.borrow_mut(), self.rank, tag, seq);
        if let Some(store) = &self.store {
            store.lock().unwrap().insert((self.rank, to, tag, seq), p.clone());
        }
        let sum = payload_checksum(&p);
        self.ev(EV_SEND, payload_bytes(&p));
        // a peer that already exited (e.g. on its own fault) has dropped
        // its inbox; the post is a no-op and its silence surfaces on this
        // side as a recv/collective timeout
        let post = |payload: Payload, checksum: u64| {
            let _ = self.senders[to].send(Msg { from: self.rank, tag, seq, checksum, payload });
        };
        match action {
            MessageAction::Deliver => post(p, sum),
            MessageAction::Drop => {
                self.stats.borrow_mut().injected += 1;
            }
            MessageAction::Delay(ms) => {
                {
                    let mut st = self.stats.borrow_mut();
                    st.injected += 1;
                    st.delayed += 1;
                }
                let t0 = self.tracer.as_ref().map(|t| t.now_ns());
                std::thread::sleep(Duration::from_millis(ms));
                if let (Some(t), Some(s0)) = (&self.tracer, t0) {
                    t.record(0, EV_DELAY, s0, t.now_ns(), payload_bytes(&p), 0);
                }
                post(p, sum);
            }
            MessageAction::Corrupt => {
                self.stats.borrow_mut().injected += 1;
                post(flip_bits(p), sum);
            }
            MessageAction::Sdc => {
                self.stats.borrow_mut().injected += 1;
                let q = poison_nan(p);
                let s2 = payload_checksum(&q);
                post(q, s2);
            }
            MessageAction::Duplicate => {
                self.stats.borrow_mut().injected += 1;
                post(p.clone(), sum);
                post(p, sum);
            }
            MessageAction::Truncate => {
                self.stats.borrow_mut().injected += 1;
                post(truncate_half(p), sum);
            }
        }
    }

    /// Blocking receive matching (from, tag), bounded by the world's
    /// `timeout_ms` deadline. Stale duplicates are dropped; corrupt or
    /// truncated payloads are healed from the retransmit store (bounded
    /// by `max_retries`); a deadline expiry makes one last store fetch
    /// before surfacing [`CommError::Timeout`].
    pub fn recv<S: CommScalar>(&mut self, from: usize, tag: u64) -> Result<Vec<S>, CommError> {
        if let Some(e) = self.fault.borrow().clone() {
            return Err(e);
        }
        let expect = *self.seq_recv.get(&(from, tag)).unwrap_or(&0);

        // 1) drain pending messages stashed while waiting on other tags
        if let Some(q) = self.pending.get_mut(&(from, tag)) {
            while !q.is_empty() && q[0].seq < expect {
                q.remove(0);
                self.stats.borrow_mut().duplicates_dropped += 1;
                self.ev(EV_DUPLICATE, 0);
            }
            if !q.is_empty() && q[0].seq == expect {
                let msg = q.remove(0);
                return self.accept::<S>(from, tag, msg);
            }
            // q[0].seq > expect: the expected message was lost in flight —
            // try the retransmit store before waiting on the channel
            if !q.is_empty() {
                if let Some(v) = self.store_accept::<S>(from, tag, expect)? {
                    return Ok(v);
                }
            }
        }

        // 2) wait on the channel under the deadline
        let start = Instant::now();
        let budget = Duration::from_millis(self.timeout_ms);
        loop {
            let msg = if self.timeout_ms == 0 {
                match self.inbox.recv() {
                    Ok(m) => m,
                    Err(_) => break, // world tearing down: fall through to timeout
                }
            } else {
                let elapsed = start.elapsed();
                if elapsed >= budget {
                    break;
                }
                match self.inbox.recv_timeout(budget - elapsed) {
                    Ok(m) => m,
                    Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {
                        break
                    }
                }
            };
            if msg.from == from && msg.tag == tag {
                if msg.seq < expect {
                    self.stats.borrow_mut().duplicates_dropped += 1;
                    self.ev(EV_DUPLICATE, 0);
                    continue;
                }
                if msg.seq > expect {
                    // gap: stash the future message, try the store for ours
                    self.pending.entry((from, tag)).or_default().push(msg);
                    if let Some(v) = self.store_accept::<S>(from, tag, expect)? {
                        return Ok(v);
                    }
                    continue;
                }
                return self.accept::<S>(from, tag, msg);
            }
            self.pending.entry((msg.from, msg.tag)).or_default().push(msg);
        }

        // 3) deadline expired: one last retransmit-store fetch
        self.stats.borrow_mut().timeouts += 1;
        self.ev(EV_TIMEOUT, 0);
        if let Some(v) = self.store_accept::<S>(from, tag, expect)? {
            return Ok(v);
        }
        let e = CommError::Timeout {
            rank: self.rank,
            peer: from,
            tag,
            elapsed_ms: start.elapsed().as_millis() as u64,
        };
        *self.fault.borrow_mut() = Some(e.clone());
        Err(e)
    }

    /// `recv` that degrades to a zero-filled buffer of `len` scalars on
    /// failure. The error stays in the poison slot, so the caller's next
    /// health check surfaces it; zero-filling lets a faulted rank finish
    /// the kernel sweep in flight instead of tearing down mid-iteration
    /// (which would leave its peers hanging until their own deadlines).
    /// Every zero-fill is counted (`CommStats::zero_fills`, plus an
    /// `EV_ZEROFILL` telemetry event), and the poison slot is guaranteed
    /// non-empty afterwards: with no active fault plan a zero-filled
    /// halo means real data loss, and the solve must end in a typed
    /// error, never a silently wrong answer.
    pub fn recv_or_zero<S: CommScalar>(&mut self, from: usize, tag: u64, len: usize) -> Vec<S> {
        match self.recv(from, tag) {
            Ok(v) => v,
            Err(e) => {
                self.stats.borrow_mut().zero_fills += 1;
                self.ev(EV_ZEROFILL, (len * std::mem::size_of::<S>()) as u64);
                let mut f = self.fault.borrow_mut();
                if f.is_none() {
                    *f = Some(CommError::Protocol(format!(
                        "rank {}: halo from {from} tag {tag} zero-filled ({e})",
                        self.rank
                    )));
                }
                if self.plan.is_empty() {
                    eprintln!(
                        "comm: rank {} zero-filled halo from {from} tag {tag} with no active fault plan: {e}",
                        self.rank
                    );
                }
                vec![S::ZERO; len]
            }
        }
    }

    /// Validate and deliver a message whose sequence number matched.
    fn accept<S: CommScalar>(
        &mut self,
        from: usize,
        tag: u64,
        msg: Msg,
    ) -> Result<Vec<S>, CommError> {
        if payload_checksum(&msg.payload) == msg.checksum {
            self.seq_recv.insert((from, tag), msg.seq + 1);
            self.store_remove(from, tag, msg.seq);
            return self.unwrap_payload(from, tag, msg.payload);
        }
        self.stats.borrow_mut().corrupt_detected += 1;
        self.ev(EV_CORRUPT, payload_bytes(&msg.payload));
        // checksum mismatch (corruption, or truncation — the payload
        // length is folded into the checksum): heal from the sender's
        // pristine copy, bounded by max_retries with exponential backoff
        // in simulated time
        for attempt in 0..self.max_retries {
            if let Some(p) = self.store_take(from, tag, msg.seq) {
                self.stats.borrow_mut().retransmits += 1;
                self.ev(EV_RETRANSMIT, payload_bytes(&p));
                self.seq_recv.insert((from, tag), msg.seq + 1);
                return self.unwrap_payload(from, tag, p);
            }
            self.stats.borrow_mut().backoff_ms += 1 << attempt;
        }
        let e = CommError::Corrupt {
            rank: self.rank,
            peer: from,
            tag,
            seq: msg.seq,
            retries: self.max_retries,
        };
        *self.fault.borrow_mut() = Some(e.clone());
        Err(e)
    }

    /// Try to deliver `seq` straight from the retransmit store (used
    /// when the channel copy is known lost or late).
    fn store_accept<S: CommScalar>(
        &mut self,
        from: usize,
        tag: u64,
        seq: u64,
    ) -> Result<Option<Vec<S>>, CommError> {
        match self.store_take(from, tag, seq) {
            Some(p) => {
                self.stats.borrow_mut().retransmits += 1;
                self.ev(EV_RETRANSMIT, payload_bytes(&p));
                self.seq_recv.insert((from, tag), seq + 1);
                self.unwrap_payload(from, tag, p).map(Some)
            }
            None => Ok(None),
        }
    }

    fn store_take(&self, from: usize, tag: u64, seq: u64) -> Option<Payload> {
        let store = self.store.as_ref()?;
        store.lock().unwrap().remove(&(from, self.rank, tag, seq))
    }

    fn store_remove(&self, from: usize, tag: u64, seq: u64) {
        if let Some(store) = &self.store {
            store.lock().unwrap().remove(&(from, self.rank, tag, seq));
        }
    }

    fn unwrap_payload<S: CommScalar>(
        &self,
        from: usize,
        tag: u64,
        p: Payload,
    ) -> Result<Vec<S>, CommError> {
        S::try_unwrap(p).map_err(|got| {
            let e = CommError::PrecisionMismatch {
                rank: self.rank,
                peer: from,
                tag,
                wanted: S::WIRE_NAME,
                got,
            };
            *self.fault.borrow_mut() = Some(e.clone());
            e
        })
    }

    /// Record a collective deadline expiry in the poison slot.
    fn poison_collective(&self) {
        self.stats.borrow_mut().timeouts += 1;
        self.ev(EV_TIMEOUT, 0);
        let mut f = self.fault.borrow_mut();
        if f.is_none() {
            *f = Some(CommError::CollectiveTimeout {
                rank: self.rank,
                elapsed_ms: self.timeout_ms,
            });
        }
    }

    /// True when this communicator is poisoned; collectives short-circuit
    /// so a dead peer costs one deadline, not one per collective.
    fn poisoned(&self) -> bool {
        self.fault.borrow().is_some()
    }

    /// The first error this communicator hit, if any (sticky).
    pub fn comm_fault(&self) -> Option<CommError> {
        self.fault.borrow().clone()
    }

    /// Snapshot of the recovery/diagnostic counters.
    pub fn stats(&self) -> CommStats {
        self.stats.borrow().clone()
    }

    /// Fault-plan matching-send cursors, for checkpointing: restoring
    /// them into a relaunched world makes the remaining triggers of a
    /// seeded plan fire at the same `(rank, tag, sequence)` points as
    /// the uninterrupted run.
    pub fn fault_cursors(&self) -> Vec<u64> {
        self.fstate.borrow().cursors()
    }

    /// Restore cursors saved by [`Comm::fault_cursors`].
    pub fn restore_fault_cursors(&self, saved: &[u64]) {
        self.fstate.borrow_mut().restore_cursors(saved);
    }

    /// Fault triggers that fired on this communicator so far, in order
    /// (`(rule index, tag, matching-send hit)`).
    pub fn fault_fired(&self) -> Vec<(usize, u64, u64)> {
        self.fstate.borrow().fired().to_vec()
    }

    /// Per-solver-iteration fault hook: applies rank-level injections
    /// (stall, kill) and surfaces any fault already in the poison slot.
    /// Distributed operators call this once per iteration through the
    /// solver health guard.
    pub fn iteration_hook(&self, iteration: usize) -> Result<(), CommError> {
        if let Some(e) = self.comm_fault() {
            return Err(e);
        }
        match self.plan.iteration_action(self.rank, self.nranks, iteration) {
            IterAction::None => Ok(()),
            IterAction::Stall(ms) => {
                self.stats.borrow_mut().injected += 1;
                std::thread::sleep(Duration::from_millis(ms));
                Ok(())
            }
            IterAction::Kill => {
                self.stats.borrow_mut().injected += 1;
                let e = CommError::Killed { rank: self.rank, iteration };
                *self.fault.borrow_mut() = Some(e.clone());
                Err(e)
            }
        }
    }

    /// Barrier over all ranks (bounded by the deadline; an expiry
    /// poisons this communicator instead of hanging the world).
    pub fn barrier(&self) {
        if self.poisoned() {
            return;
        }
        if !self.barrier.wait(self.timeout_ms) {
            self.poison_collective();
        }
    }

    /// Sum a scalar across all ranks (two-phase with shared slots). On a
    /// deadline expiry the local value is returned and the communicator
    /// is poisoned — the solver health guard surfaces the fault.
    pub fn allreduce_sum(&self, value: f64) -> f64 {
        if self.poisoned() {
            return value;
        }
        {
            let mut slots = self.reduce_slots.lock().unwrap();
            slots[self.rank] = value;
        }
        if !self.reduce_barrier.wait(self.timeout_ms) {
            self.poison_collective();
            return value;
        }
        let total: f64 = self.reduce_slots.lock().unwrap().iter().sum();
        // second barrier so no rank overwrites its slot for the next call
        // before everyone has read
        if !self.reduce_barrier.wait(self.timeout_ms) {
            self.poison_collective();
        }
        total
    }

    /// Collective: post this rank's wire signature, return everyone's.
    /// (Internal to [`validate_wire_format`]; collective calls must be
    /// made in the same order on every rank.)
    fn exchange_sigs(&self, sig: u64) -> Vec<u64> {
        if self.poisoned() {
            return vec![sig; self.nranks];
        }
        {
            let mut slots = self.sig_slots.lock().unwrap();
            slots[self.rank] = sig;
        }
        if !self.coll_barrier.wait(self.timeout_ms) {
            self.poison_collective();
            return vec![sig; self.nranks];
        }
        let sigs = self.sig_slots.lock().unwrap().clone();
        // second barrier so no rank posts its next signature before
        // everyone has read this round
        if !self.coll_barrier.wait(self.timeout_ms) {
            self.poison_collective();
        }
        sigs
    }

    /// Gather every rank's f64 vector (rank-indexed). The distributed
    /// multi-RHS operators use this to fold per-tile reduction partials
    /// in *global* site-tile order, which keeps solver scalars bitwise
    /// independent of the rank count. Collective: every rank must call
    /// with the same sequence of gathers.
    pub fn allgather_f64(&self, v: &[f64]) -> Vec<Vec<f64>> {
        if self.poisoned() {
            return vec![v.to_vec(); self.nranks];
        }
        {
            let mut slots = self.gather_slots.lock().unwrap();
            slots[self.rank] = v.to_vec();
        }
        if !self.coll_barrier.wait(self.timeout_ms) {
            self.poison_collective();
            return vec![v.to_vec(); self.nranks];
        }
        let all = self.gather_slots.lock().unwrap().clone();
        if !self.coll_barrier.wait(self.timeout_ms) {
            self.poison_collective();
        }
        all
    }

    /// Collective OR of a per-rank flag: lets the solvers take globally
    /// consistent control-flow decisions (e.g. warm-start detection)
    /// without divergent collective sequences.
    pub fn allreduce_any(&self, v: bool) -> bool {
        self.exchange_sigs(u64::from(v)).iter().any(|&s| s != 0)
    }
}

/// World-construction knobs: deadlines, retransmit bounds, and the fault
/// plan. `Default` gives a 30 s deadline, 3 retries, and no faults.
#[derive(Clone, Debug)]
pub struct WorldOpts {
    /// recv/collective deadline in ms; 0 = wait forever
    pub timeout_ms: u64,
    /// retransmit attempts per corrupt/truncated message
    pub max_retries: u32,
    pub faults: FaultPlan,
}

impl Default for WorldOpts {
    fn default() -> WorldOpts {
        WorldOpts { timeout_ms: 30_000, max_retries: 3, faults: FaultPlan::none() }
    }
}

/// Run `f(rank, comm)` on `nranks` threads with default [`WorldOpts`];
/// returns the per-rank results in rank order.
pub fn run_world<T, F>(nranks: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, &mut Comm) -> T + Sync,
{
    run_world_cfg(nranks, WorldOpts::default(), f)
}

/// Run `f(rank, comm)` on `nranks` threads under explicit transport
/// options; returns the per-rank results in rank order. A rank thread's
/// panic is re-raised on the caller (with its original payload) instead
/// of being masked by a join `expect`.
pub fn run_world_cfg<T, F>(nranks: usize, opts: WorldOpts, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, &mut Comm) -> T + Sync,
{
    assert!(nranks > 0);
    let mut senders = Vec::with_capacity(nranks);
    let mut inboxes = Vec::with_capacity(nranks);
    for _ in 0..nranks {
        let (tx, rx) = channel();
        senders.push(tx);
        inboxes.push(rx);
    }
    let barrier = Arc::new(TimedBarrier::new(nranks));
    let reduce_slots = Arc::new(Mutex::new(vec![0.0f64; nranks]));
    let reduce_barrier = Arc::new(TimedBarrier::new(nranks));
    let sig_slots = Arc::new(Mutex::new(vec![0u64; nranks]));
    let gather_slots = Arc::new(Mutex::new(vec![Vec::new(); nranks]));
    let coll_barrier = Arc::new(TimedBarrier::new(nranks));
    let plan = Arc::new(opts.faults);
    // the retransmit store is only armed under an active fault plan: the
    // in-process channel cannot lose bytes on its own, so the fault-free
    // hot path pays no pristine-copy clones
    let store: Option<RetransmitStore> = if plan.is_empty() {
        None
    } else {
        Some(Arc::new(Mutex::new(HashMap::new())))
    };

    let mut comms: Vec<Comm> = inboxes
        .into_iter()
        .enumerate()
        .map(|(rank, inbox)| Comm {
            rank,
            nranks,
            senders: senders.clone(),
            inbox,
            pending: HashMap::new(),
            timeout_ms: opts.timeout_ms,
            max_retries: opts.max_retries,
            plan: Arc::clone(&plan),
            fstate: RefCell::new(plan.new_state()),
            seq_send: RefCell::new(HashMap::new()),
            seq_recv: HashMap::new(),
            store: store.clone(),
            stats: RefCell::new(CommStats::default()),
            fault: RefCell::new(None),
            barrier: Arc::clone(&barrier),
            reduce_slots: Arc::clone(&reduce_slots),
            reduce_barrier: Arc::clone(&reduce_barrier),
            sig_slots: Arc::clone(&sig_slots),
            gather_slots: Arc::clone(&gather_slots),
            coll_barrier: Arc::clone(&coll_barrier),
            tracer: None,
        })
        .collect();
    // drop the original senders so channels close when the world ends
    drop(senders);

    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(nranks);
        for (rank, mut comm) in comms.drain(..).enumerate() {
            let f = &f;
            handles.push(scope.spawn(move || f(rank, &mut comm)));
        }
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|p| std::panic::resume_unwind(p)))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn faulty(spec: &str, timeout_ms: u64) -> WorldOpts {
        WorldOpts {
            timeout_ms,
            max_retries: 3,
            faults: FaultPlan::parse(spec).unwrap(),
        }
    }

    #[test]
    fn ring_pass() {
        let results = run_world(4, |rank, comm| {
            let next = (rank + 1) % 4;
            let prev = (rank + 3) % 4;
            comm.send(next, 7, vec![rank as f32]);
            let got: Vec<f32> = comm.recv(prev, 7).unwrap();
            got[0] as usize
        });
        assert_eq!(results, vec![3, 0, 1, 2]);
    }

    #[test]
    fn tags_disambiguate() {
        let results = run_world(2, |rank, comm| {
            let other = 1 - rank;
            comm.send(other, 1, vec![10.0 + rank as f32]);
            comm.send(other, 2, vec![20.0 + rank as f32]);
            // receive in the opposite order to exercise the pending queue
            let b: Vec<f32> = comm.recv(other, 2).unwrap();
            let a: Vec<f32> = comm.recv(other, 1).unwrap();
            (a[0], b[0])
        });
        assert_eq!(results[0], (11.0, 21.0));
        assert_eq!(results[1], (10.0, 20.0));
    }

    #[test]
    fn self_send() {
        // the paper enforces communication with the self process
        let results = run_world(1, |_, comm| {
            comm.send(0, 3, vec![1.0f32, 2.0]);
            comm.recv::<f32>(0, 3).unwrap()
        });
        assert_eq!(results[0], vec![1.0, 2.0]);
    }

    #[test]
    fn allreduce() {
        let results = run_world(3, |rank, comm| {
            let a = comm.allreduce_sum(rank as f64 + 1.0);
            let b = comm.allreduce_sum(rank as f64 * 10.0);
            (a, b)
        });
        for (a, b) in results {
            assert_eq!(a, 6.0);
            assert_eq!(b, 30.0);
        }
    }

    #[test]
    fn wire_sig_roundtrip_and_decode() {
        let sig = wire_sig::<f32>(3, &[true, false, true]);
        assert_eq!(sig & 0xffff_ffff, 0b101);
        assert_eq!((sig >> 32) & 0xfff, 3);
        assert_eq!(sig >> 44, 1);
        let s = decode_wire_sig(sig);
        assert!(s.contains("f32") && s.contains("nrhs 3") && s.contains("101"), "{s}");
        let sig64 = wire_sig::<f64>(2, &[true, true]);
        assert!(decode_wire_sig(sig64).contains("f64"));
        assert_ne!(sig, sig64);
    }

    #[test]
    fn wire_format_handshake_agrees_and_disagrees() {
        // matching formats: every rank gets Ok
        let results = run_world(3, |_, comm| {
            validate_wire_format::<f32>(comm, 2, &[true, false]).is_ok()
        });
        assert!(results.iter().all(|&ok| ok));

        // mask desync: every rank gets a structured error naming ranks
        let results = run_world(2, |rank, comm| {
            let active = if rank == 0 { [true, true] } else { [true, false] };
            validate_wire_format::<f32>(comm, 2, &active).unwrap_err().to_string()
        });
        for msg in &results {
            assert!(msg.contains("rank 0") && msg.contains("rank 1"), "{msg}");
            assert!(msg.contains("before any payload was sent"), "{msg}");
        }

        // precision desync: the decoded error names both precisions
        let results = run_world(2, |rank, comm| {
            if rank == 0 {
                validate_wire_format::<f32>(comm, 1, &[true]).unwrap_err().to_string()
            } else {
                validate_wire_format::<f64>(comm, 1, &[true]).unwrap_err().to_string()
            }
        });
        assert!(results[0].contains("f32") && results[0].contains("f64"));
    }

    #[test]
    fn oversized_batch_is_structured_error_not_a_hang() {
        // every rank over the cap gets Err; none deadlocks at the barrier
        let results = run_world(2, |_, comm| {
            let active = vec![true; 40];
            validate_wire_format::<f32>(comm, 40, &active).unwrap_err().to_string()
        });
        for m in &results {
            assert!(m.contains("at most 32") && m.contains("got nrhs 40"), "{m}");
        }
        // one oversized rank + one valid rank: the valid rank sees a
        // mismatch (sentinel sig), the oversized one its overflow error
        let results = run_world(2, |rank, comm| {
            if rank == 0 {
                validate_wire_format::<f32>(comm, 2, &[true, true])
                    .unwrap_err()
                    .to_string()
            } else {
                validate_wire_format::<f32>(comm, 40, &vec![true; 40])
                    .unwrap_err()
                    .to_string()
            }
        });
        assert!(results[0].contains("mismatch"), "{}", results[0]);
        assert!(results[1].contains("at most 32"), "{}", results[1]);
    }

    #[test]
    fn allgather_returns_rank_ordered_vectors() {
        let results = run_world(3, |rank, comm| {
            let mine = vec![rank as f64, 10.0 * rank as f64];
            let all = comm.allgather_f64(&mine);
            // a second round must not see stale slots
            let all2 = comm.allgather_f64(&[100.0 + rank as f64]);
            (all, all2)
        });
        for (all, all2) in results {
            for r in 0..3 {
                assert_eq!(all[r], vec![r as f64, 10.0 * r as f64]);
                assert_eq!(all2[r], vec![100.0 + r as f64]);
            }
        }
    }

    #[test]
    fn allreduce_any_ors_flags() {
        let results = run_world(3, |rank, comm| {
            let a = comm.allreduce_any(rank == 1);
            let b = comm.allreduce_any(false);
            (a, b)
        });
        for (a, b) in results {
            assert!(a);
            assert!(!b);
        }
    }

    #[test]
    fn same_tag_ordering_preserved() {
        let results = run_world(2, |rank, comm| {
            if rank == 0 {
                comm.send(1, 5, vec![1.0f32]);
                comm.send(1, 5, vec![2.0f32]);
                vec![]
            } else {
                let a: Vec<f32> = comm.recv(0, 5).unwrap();
                let b: Vec<f32> = comm.recv(0, 5).unwrap();
                vec![a[0], b[0]]
            }
        });
        assert_eq!(results[1], vec![1.0, 2.0]);
    }

    #[test]
    fn recv_timeout_is_structured_not_a_hang() {
        let t0 = Instant::now();
        let results = run_world_cfg(2, faulty("", 80), |rank, comm| {
            if rank == 0 {
                // never sends
                0
            } else {
                match comm.recv::<f32>(0, 9) {
                    Ok(_) => 1,
                    Err(CommError::Timeout { rank, peer, tag, .. }) => {
                        assert_eq!((rank, peer, tag), (1, 0, 9));
                        // the poison slot short-circuits the next call
                        assert!(comm.recv::<f32>(0, 10).is_err());
                        assert_eq!(comm.stats().timeouts, 1);
                        2
                    }
                    Err(e) => panic!("wrong error {e}"),
                }
            }
        });
        assert_eq!(results, vec![0, 2]);
        assert!(t0.elapsed() < Duration::from_secs(10));
    }

    #[test]
    fn dropped_message_heals_from_retransmit_store() {
        let results = run_world_cfg(2, faulty("drop:rank=0,tag=4,nth=1", 100), |rank, comm| {
            if rank == 0 {
                comm.send(1, 4, vec![1.5f64, 2.5]);
                (vec![], comm.stats())
            } else {
                let v: Vec<f64> = comm.recv(0, 4).unwrap();
                (v, comm.stats())
            }
        });
        let (v, stats) = &results[1];
        assert_eq!(v, &vec![1.5, 2.5], "store copy must be pristine");
        assert_eq!(stats.retransmits, 1);
        assert_eq!(stats.timeouts, 1);
        let sender = &results[0].1;
        assert_eq!(sender.injected, 1);
    }

    #[test]
    fn corrupt_message_detected_and_healed_bitwise() {
        let results =
            run_world_cfg(2, faulty("corrupt:rank=0,tag=6,nth=1", 200), |rank, comm| {
                if rank == 0 {
                    comm.send(1, 6, vec![3.25f32, -7.5]);
                    vec![]
                } else {
                    let v: Vec<f32> = comm.recv(0, 6).unwrap();
                    let st = comm.stats();
                    assert_eq!(st.corrupt_detected, 1);
                    assert_eq!(st.retransmits, 1);
                    assert_eq!(st.timeouts, 0, "heal must not wait for the deadline");
                    v
                }
            });
        assert_eq!(results[1], vec![3.25, -7.5]);
    }

    #[test]
    fn truncated_message_detected_and_healed() {
        let results =
            run_world_cfg(2, faulty("truncate:rank=0,tag=2,nth=1", 200), |rank, comm| {
                if rank == 0 {
                    comm.send(1, 2, vec![1.0f64, 2.0, 3.0, 4.0]);
                    vec![]
                } else {
                    let v: Vec<f64> = comm.recv(0, 2).unwrap();
                    assert_eq!(comm.stats().corrupt_detected, 1);
                    assert_eq!(comm.stats().retransmits, 1);
                    v
                }
            });
        assert_eq!(results[1], vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn duplicate_delivery_dropped_by_stale_sequence() {
        let results =
            run_world_cfg(2, faulty("duplicate:rank=0,tag=8,nth=1", 200), |rank, comm| {
                if rank == 0 {
                    comm.send(1, 8, vec![1.0f32]);
                    comm.send(1, 8, vec![2.0f32]);
                    0
                } else {
                    let a: Vec<f32> = comm.recv(0, 8).unwrap();
                    let b: Vec<f32> = comm.recv(0, 8).unwrap();
                    assert_eq!((a[0], b[0]), (1.0, 2.0));
                    assert_eq!(comm.stats().duplicates_dropped, 1);
                    1
                }
            });
        assert_eq!(results, vec![0, 1]);
    }

    #[test]
    fn delayed_message_arrives_late_but_intact() {
        let results =
            run_world_cfg(2, faulty("delay:rank=0,tag=3,nth=1,ms=30", 1000), |rank, comm| {
                if rank == 0 {
                    comm.send(1, 3, vec![9.0f32]);
                    comm.stats().delayed
                } else {
                    let v: Vec<f32> = comm.recv(0, 3).unwrap();
                    assert_eq!(v, vec![9.0]);
                    0
                }
            });
        assert_eq!(results[0], 1);
    }

    #[test]
    fn precision_mismatch_is_structured_error() {
        let results = run_world_cfg(2, faulty("", 200), |rank, comm| {
            if rank == 0 {
                comm.send(1, 1, vec![1.0f32]);
                String::new()
            } else {
                comm.recv::<f64>(0, 1).unwrap_err().to_string()
            }
        });
        assert!(
            results[1].contains("recv precision mismatch")
                && results[1].contains("wanted f64")
                && results[1].contains("got f32"),
            "{}",
            results[1]
        );
    }

    #[test]
    fn collective_timeout_poisons_instead_of_hanging() {
        let t0 = Instant::now();
        let results = run_world_cfg(2, faulty("", 60), |rank, comm| {
            if rank == 0 {
                // never joins the collective
                (0.0, None)
            } else {
                let v = comm.allreduce_sum(5.0);
                (v, comm.comm_fault())
            }
        });
        assert_eq!(results[1].0, 5.0, "degrades to the local value");
        assert!(
            matches!(results[1].1, Some(CommError::CollectiveTimeout { rank: 1, .. })),
            "{:?}",
            results[1].1
        );
        assert!(t0.elapsed() < Duration::from_secs(10));
    }

    #[test]
    fn kill_hook_poisons_and_shortcircuits() {
        let results = run_world_cfg(2, faulty("kill:rank=1,iter=2", 60), |rank, comm| {
            if rank == 0 {
                // survives its iterations, then times out at the reduce
                for it in 0..3 {
                    comm.iteration_hook(it).unwrap();
                }
                let _ = comm.allreduce_sum(1.0);
                comm.comm_fault().map(|e| e.to_string())
            } else {
                for it in 0..3 {
                    if let Err(e) = comm.iteration_hook(it) {
                        assert!(
                            matches!(e, CommError::Killed { rank: 1, iteration: 2 }),
                            "{e}"
                        );
                        // poisoned: collectives short-circuit immediately
                        let _ = comm.allreduce_sum(1.0);
                        return comm.comm_fault().map(|e| e.to_string());
                    }
                }
                None
            }
        });
        let killed = results[1].as_ref().expect("victim must carry the kill fault");
        assert!(killed.contains("killed by fault injection"), "{killed}");
        assert!(killed.contains("iteration 2"), "{killed}");
        let peer = results[0].as_ref().expect("peer must time out");
        assert!(peer.contains("collective timeout"), "{peer}");
    }

    #[test]
    fn recv_or_zero_degrades_and_records_fault() {
        let results = run_world_cfg(2, faulty("", 50), |rank, comm| {
            if rank == 0 {
                (vec![], None, 0)
            } else {
                let v: Vec<f64> = comm.recv_or_zero(0, 11, 4);
                (v, comm.comm_fault(), comm.stats().zero_fills)
            }
        });
        assert_eq!(results[1].0, vec![0.0; 4]);
        assert!(matches!(results[1].1, Some(CommError::Timeout { .. })));
        assert_eq!(results[1].2, 1, "zero-fill must be counted");
    }
}
