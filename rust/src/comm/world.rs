//! Simulated MPI: an in-process rank world.
//!
//! Each rank is an OS thread; point-to-point messages travel over
//! channels. The API mirrors the MPI subset the paper's code needs:
//! tagged send/recv, barrier, and an all-reduce (for solver dot
//! products). Communication is FUNNELED as on Fugaku (§3.6): only the
//! rank's master thread calls these functions.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier, Mutex};

/// A wire buffer: halo payloads travel at the precision of the field
/// they were packed from (12 reals per site either way).
#[derive(Clone, Debug)]
pub enum Payload {
    F32(Vec<f32>),
    F64(Vec<f64>),
}

/// Scalars that can travel through the simulated-MPI world. Implemented
/// for `f32` and `f64`; a `recv` with the wrong precision for the
/// matching send panics loudly (a type confusion, never a silent cast).
pub trait CommScalar: Copy + Send + 'static {
    fn wrap(v: Vec<Self>) -> Payload;
    fn unwrap(p: Payload) -> Vec<Self>;
}

impl CommScalar for f32 {
    fn wrap(v: Vec<f32>) -> Payload {
        Payload::F32(v)
    }

    fn unwrap(p: Payload) -> Vec<f32> {
        match p {
            Payload::F32(v) => v,
            Payload::F64(_) => panic!("recv precision mismatch: wanted f32, got f64"),
        }
    }
}

impl CommScalar for f64 {
    fn wrap(v: Vec<f64>) -> Payload {
        Payload::F64(v)
    }

    fn unwrap(p: Payload) -> Vec<f64> {
        match p {
            Payload::F64(v) => v,
            Payload::F32(_) => panic!("recv precision mismatch: wanted f64, got f32"),
        }
    }
}

/// A tagged message.
struct Msg {
    from: usize,
    tag: u64,
    payload: Payload,
}

/// Per-rank communicator handle.
pub struct Comm {
    pub rank: usize,
    pub nranks: usize,
    senders: Vec<Sender<Msg>>,
    inbox: Receiver<Msg>,
    /// messages that arrived while waiting for a different (from, tag)
    pending: HashMap<(usize, u64), Vec<Payload>>,
    barrier: Arc<Barrier>,
    reduce_slots: Arc<Mutex<Vec<f64>>>,
    reduce_barrier: Arc<Barrier>,
}

impl Comm {
    /// Non-blocking send (buffered by the channel).
    pub fn send<S: CommScalar>(&self, to: usize, tag: u64, payload: Vec<S>) {
        self.senders[to]
            .send(Msg {
                from: self.rank,
                tag,
                payload: S::wrap(payload),
            })
            .expect("rank channel closed");
    }

    /// Blocking receive matching (from, tag).
    pub fn recv<S: CommScalar>(&mut self, from: usize, tag: u64) -> Vec<S> {
        if let Some(queue) = self.pending.get_mut(&(from, tag)) {
            if !queue.is_empty() {
                return S::unwrap(queue.remove(0));
            }
        }
        loop {
            let msg = self.inbox.recv().expect("rank channel closed");
            if msg.from == from && msg.tag == tag {
                return S::unwrap(msg.payload);
            }
            self.pending
                .entry((msg.from, msg.tag))
                .or_default()
                .push(msg.payload);
        }
    }

    /// Barrier over all ranks.
    pub fn barrier(&self) {
        self.barrier.wait();
    }

    /// Sum a scalar across all ranks (two-phase with shared slots).
    pub fn allreduce_sum(&self, value: f64) -> f64 {
        {
            let mut slots = self.reduce_slots.lock().unwrap();
            slots[self.rank] = value;
        }
        self.reduce_barrier.wait();
        let total: f64 = self.reduce_slots.lock().unwrap().iter().sum();
        // second barrier so no rank overwrites its slot for the next call
        // before everyone has read
        self.reduce_barrier.wait();
        total
    }
}

/// Run `f(rank, comm)` on `nranks` threads; returns the per-rank results
/// in rank order.
pub fn run_world<T, F>(nranks: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, &mut Comm) -> T + Sync,
{
    assert!(nranks > 0);
    let mut senders = Vec::with_capacity(nranks);
    let mut inboxes = Vec::with_capacity(nranks);
    for _ in 0..nranks {
        let (tx, rx) = channel();
        senders.push(tx);
        inboxes.push(rx);
    }
    let barrier = Arc::new(Barrier::new(nranks));
    let reduce_slots = Arc::new(Mutex::new(vec![0.0f64; nranks]));
    let reduce_barrier = Arc::new(Barrier::new(nranks));

    let mut comms: Vec<Comm> = inboxes
        .into_iter()
        .enumerate()
        .map(|(rank, inbox)| Comm {
            rank,
            nranks,
            senders: senders.clone(),
            inbox,
            pending: HashMap::new(),
            barrier: Arc::clone(&barrier),
            reduce_slots: Arc::clone(&reduce_slots),
            reduce_barrier: Arc::clone(&reduce_barrier),
        })
        .collect();
    // drop the original senders so channels close when the world ends
    drop(senders);

    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(nranks);
        for (rank, mut comm) in comms.drain(..).enumerate() {
            let f = &f;
            handles.push(scope.spawn(move || f(rank, &mut comm)));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("rank thread panicked"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_pass() {
        let results = run_world(4, |rank, comm| {
            let next = (rank + 1) % 4;
            let prev = (rank + 3) % 4;
            comm.send(next, 7, vec![rank as f32]);
            let got: Vec<f32> = comm.recv(prev, 7);
            got[0] as usize
        });
        assert_eq!(results, vec![3, 0, 1, 2]);
    }

    #[test]
    fn tags_disambiguate() {
        let results = run_world(2, |rank, comm| {
            let other = 1 - rank;
            comm.send(other, 1, vec![10.0 + rank as f32]);
            comm.send(other, 2, vec![20.0 + rank as f32]);
            // receive in the opposite order to exercise the pending queue
            let b: Vec<f32> = comm.recv(other, 2);
            let a: Vec<f32> = comm.recv(other, 1);
            (a[0], b[0])
        });
        assert_eq!(results[0], (11.0, 21.0));
        assert_eq!(results[1], (10.0, 20.0));
    }

    #[test]
    fn self_send() {
        // the paper enforces communication with the self process
        let results = run_world(1, |_, comm| {
            comm.send(0, 3, vec![1.0f32, 2.0]);
            comm.recv::<f32>(0, 3)
        });
        assert_eq!(results[0], vec![1.0, 2.0]);
    }

    #[test]
    fn allreduce() {
        let results = run_world(3, |rank, comm| {
            let a = comm.allreduce_sum(rank as f64 + 1.0);
            let b = comm.allreduce_sum(rank as f64 * 10.0);
            (a, b)
        });
        for (a, b) in results {
            assert_eq!(a, 6.0);
            assert_eq!(b, 30.0);
        }
    }

    #[test]
    fn same_tag_ordering_preserved() {
        let results = run_world(2, |rank, comm| {
            if rank == 0 {
                comm.send(1, 5, vec![1.0f32]);
                comm.send(1, 5, vec![2.0f32]);
                vec![]
            } else {
                let a: Vec<f32> = comm.recv(0, 5);
                let b: Vec<f32> = comm.recv(0, 5);
                vec![a[0], b[0]]
            }
        });
        assert_eq!(results[1], vec![1.0, 2.0]);
    }
}
