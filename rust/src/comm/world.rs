//! Simulated MPI: an in-process rank world.
//!
//! Each rank is an OS thread; point-to-point messages travel over
//! channels. The API mirrors the MPI subset the paper's code needs:
//! tagged send/recv, barrier, and an all-reduce (for solver dot
//! products). Communication is FUNNELED as on Fugaku (§3.6): only the
//! rank's master thread calls these functions.

use std::collections::HashMap;
use std::fmt;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier, Mutex};

/// A wire buffer: halo payloads travel at the precision of the field
/// they were packed from (12 reals per site either way).
#[derive(Clone, Debug)]
pub enum Payload {
    F32(Vec<f32>),
    F64(Vec<f64>),
}

/// A structured communication-layer error: what went wrong and which
/// ranks disagreed, surfaced *before* any payload is posted (see
/// [`validate_wire_format`]) instead of a type panic mid-exchange.
#[derive(Clone, Debug)]
pub struct CommError(pub String);

impl fmt::Display for CommError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for CommError {}

/// Scalars that can travel through the simulated-MPI world. Implemented
/// for `f32` and `f64`; a `recv` with the wrong precision for the
/// matching send panics loudly (a type confusion, never a silent cast).
/// The [`validate_wire_format`] handshake exists to catch that confusion
/// *before* the first send, as a structured [`CommError`].
pub trait CommScalar: Copy + Send + 'static {
    /// Wire identifier of this scalar (part of the halo wire signature).
    const WIRE_ID: u64;
    /// Human name used when decoding a wire-signature mismatch.
    const WIRE_NAME: &'static str;

    fn wrap(v: Vec<Self>) -> Payload;
    fn unwrap(p: Payload) -> Vec<Self>;
}

impl CommScalar for f32 {
    const WIRE_ID: u64 = 1;
    const WIRE_NAME: &'static str = "f32";

    fn wrap(v: Vec<f32>) -> Payload {
        Payload::F32(v)
    }

    fn unwrap(p: Payload) -> Vec<f32> {
        match p {
            Payload::F32(v) => v,
            Payload::F64(_) => panic!("recv precision mismatch: wanted f32, got f64"),
        }
    }
}

impl CommScalar for f64 {
    const WIRE_ID: u64 = 2;
    const WIRE_NAME: &'static str = "f64";

    fn wrap(v: Vec<f64>) -> Payload {
        Payload::F64(v)
    }

    fn unwrap(p: Payload) -> Vec<f64> {
        match p {
            Payload::F64(v) => v,
            Payload::F32(_) => panic!("recv precision mismatch: wanted f64, got f32"),
        }
    }
}

/// Most right-hand sides a batched halo message can describe: the active
/// mask must fit the wire signature's 32 mask bits.
pub const MAX_WIRE_RHS: usize = 32;

/// Sentinel signature a rank posts when its own batch is unencodable
/// (`nrhs > MAX_WIRE_RHS`): it still joins the collective — so no rank
/// hangs at the barrier — and can never equal a valid signature
/// (precision nibble 0xF).
const OVERFLOW_SIG: u64 = 0xF << 44;

/// Encode the halo wire format — (precision, nrhs, active mask) — into
/// one u64 so every rank can compare formats in a single collective and
/// batched message tags can carry the format they were packed under.
/// Bits: `[0, 32)` active mask, `[32, 44)` nrhs, `[44, 48)` precision id.
///
/// Panics when `nrhs > MAX_WIRE_RHS`; the batched exchange only calls
/// this after [`validate_wire_format`] succeeded (which reports the
/// overflow as a structured error instead), so the assert is a
/// defense-in-depth invariant, not a reachable failure mode.
pub fn wire_sig<S: CommScalar>(nrhs: usize, active: &[bool]) -> u64 {
    assert!(
        nrhs <= MAX_WIRE_RHS,
        "batched halos support at most {MAX_WIRE_RHS} RHS per message (got {nrhs})"
    );
    debug_assert_eq!(active.len(), nrhs);
    let mut mask = 0u64;
    for (r, &on) in active.iter().enumerate() {
        if on {
            mask |= 1 << r;
        }
    }
    mask | ((nrhs as u64) << 32) | (S::WIRE_ID << 44)
}

/// Decode a wire signature for error reporting.
pub fn decode_wire_sig(sig: u64) -> String {
    let mask = sig & 0xffff_ffff;
    let nrhs = ((sig >> 32) & 0xfff) as usize;
    let prec = match sig >> 44 {
        1 => "f32",
        2 => "f64",
        _ => "?",
    };
    let mask_str: String = (0..nrhs.min(MAX_WIRE_RHS))
        .map(|r| if mask & (1 << r) != 0 { '1' } else { '0' })
        .collect();
    format!("precision {prec}, nrhs {nrhs}, active mask [{mask_str}]")
}

/// Wire-format handshake: every rank posts its (precision, nrhs, active
/// mask) signature and compares against the whole world. Run BEFORE the
/// first halo send of a batched exchange, so a rank-count, precision or
/// mask desync surfaces as one structured [`CommError`] naming the
/// disagreeing ranks — instead of a type panic (or a tag-mismatch hang)
/// in the middle of the exchange.
pub fn validate_wire_format<S: CommScalar>(
    comm: &Comm,
    nrhs: usize,
    active: &[bool],
) -> Result<(), CommError> {
    // an unencodable batch still joins the collective (sentinel sig) so
    // the other ranks are never left hanging at the barrier, then
    // reports the overflow as a structured error on every rank
    let sig = if nrhs <= MAX_WIRE_RHS {
        wire_sig::<S>(nrhs, active)
    } else {
        OVERFLOW_SIG
    };
    let sigs = comm.exchange_sigs(sig);
    if nrhs > MAX_WIRE_RHS {
        return Err(CommError(format!(
            "batched halos carry at most {MAX_WIRE_RHS} right-hand sides per \
             message (the wire signature's mask width); got nrhs {nrhs}"
        )));
    }
    if sigs.iter().all(|&s| s == sig) {
        return Ok(());
    }
    let lines: Vec<String> = sigs
        .iter()
        .enumerate()
        .map(|(r, &s)| format!("  rank {r}: {}", decode_wire_sig(s)))
        .collect();
    Err(CommError(format!(
        "halo wire-format mismatch across the rank world (detected before any \
         payload was sent):\n{}",
        lines.join("\n")
    )))
}

/// A tagged message.
struct Msg {
    from: usize,
    tag: u64,
    payload: Payload,
}

/// Per-rank communicator handle.
pub struct Comm {
    pub rank: usize,
    pub nranks: usize,
    senders: Vec<Sender<Msg>>,
    inbox: Receiver<Msg>,
    /// messages that arrived while waiting for a different (from, tag)
    pending: HashMap<(usize, u64), Vec<Payload>>,
    barrier: Arc<Barrier>,
    reduce_slots: Arc<Mutex<Vec<f64>>>,
    reduce_barrier: Arc<Barrier>,
    /// wire-signature slots for the pre-exchange format handshake
    sig_slots: Arc<Mutex<Vec<u64>>>,
    /// per-rank vector slots for `allgather_f64`
    gather_slots: Arc<Mutex<Vec<Vec<f64>>>>,
    /// barrier shared by the sig/gather collectives (all collective calls
    /// are made in identical order on every rank, so one barrier serves)
    coll_barrier: Arc<Barrier>,
}

impl Comm {
    /// Non-blocking send (buffered by the channel).
    pub fn send<S: CommScalar>(&self, to: usize, tag: u64, payload: Vec<S>) {
        self.senders[to]
            .send(Msg {
                from: self.rank,
                tag,
                payload: S::wrap(payload),
            })
            .expect("rank channel closed");
    }

    /// Blocking receive matching (from, tag).
    pub fn recv<S: CommScalar>(&mut self, from: usize, tag: u64) -> Vec<S> {
        if let Some(queue) = self.pending.get_mut(&(from, tag)) {
            if !queue.is_empty() {
                return S::unwrap(queue.remove(0));
            }
        }
        loop {
            let msg = self.inbox.recv().expect("rank channel closed");
            if msg.from == from && msg.tag == tag {
                return S::unwrap(msg.payload);
            }
            self.pending
                .entry((msg.from, msg.tag))
                .or_default()
                .push(msg.payload);
        }
    }

    /// Barrier over all ranks.
    pub fn barrier(&self) {
        self.barrier.wait();
    }

    /// Sum a scalar across all ranks (two-phase with shared slots).
    pub fn allreduce_sum(&self, value: f64) -> f64 {
        {
            let mut slots = self.reduce_slots.lock().unwrap();
            slots[self.rank] = value;
        }
        self.reduce_barrier.wait();
        let total: f64 = self.reduce_slots.lock().unwrap().iter().sum();
        // second barrier so no rank overwrites its slot for the next call
        // before everyone has read
        self.reduce_barrier.wait();
        total
    }

    /// Collective: post this rank's wire signature, return everyone's.
    /// (Internal to [`validate_wire_format`]; collective calls must be
    /// made in the same order on every rank.)
    fn exchange_sigs(&self, sig: u64) -> Vec<u64> {
        {
            let mut slots = self.sig_slots.lock().unwrap();
            slots[self.rank] = sig;
        }
        self.coll_barrier.wait();
        let sigs = self.sig_slots.lock().unwrap().clone();
        // second barrier so no rank posts its next signature before
        // everyone has read this round
        self.coll_barrier.wait();
        sigs
    }

    /// Gather every rank's f64 vector (rank-indexed). The distributed
    /// multi-RHS operators use this to fold per-tile reduction partials
    /// in *global* site-tile order, which keeps solver scalars bitwise
    /// independent of the rank count. Collective: every rank must call
    /// with the same sequence of gathers.
    pub fn allgather_f64(&self, v: &[f64]) -> Vec<Vec<f64>> {
        {
            let mut slots = self.gather_slots.lock().unwrap();
            slots[self.rank] = v.to_vec();
        }
        self.coll_barrier.wait();
        let all = self.gather_slots.lock().unwrap().clone();
        self.coll_barrier.wait();
        all
    }

    /// Collective OR of a per-rank flag: lets the solvers take globally
    /// consistent control-flow decisions (e.g. warm-start detection)
    /// without divergent collective sequences.
    pub fn allreduce_any(&self, v: bool) -> bool {
        self.exchange_sigs(u64::from(v)).iter().any(|&s| s != 0)
    }
}

/// Run `f(rank, comm)` on `nranks` threads; returns the per-rank results
/// in rank order.
pub fn run_world<T, F>(nranks: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, &mut Comm) -> T + Sync,
{
    assert!(nranks > 0);
    let mut senders = Vec::with_capacity(nranks);
    let mut inboxes = Vec::with_capacity(nranks);
    for _ in 0..nranks {
        let (tx, rx) = channel();
        senders.push(tx);
        inboxes.push(rx);
    }
    let barrier = Arc::new(Barrier::new(nranks));
    let reduce_slots = Arc::new(Mutex::new(vec![0.0f64; nranks]));
    let reduce_barrier = Arc::new(Barrier::new(nranks));
    let sig_slots = Arc::new(Mutex::new(vec![0u64; nranks]));
    let gather_slots = Arc::new(Mutex::new(vec![Vec::new(); nranks]));
    let coll_barrier = Arc::new(Barrier::new(nranks));

    let mut comms: Vec<Comm> = inboxes
        .into_iter()
        .enumerate()
        .map(|(rank, inbox)| Comm {
            rank,
            nranks,
            senders: senders.clone(),
            inbox,
            pending: HashMap::new(),
            barrier: Arc::clone(&barrier),
            reduce_slots: Arc::clone(&reduce_slots),
            reduce_barrier: Arc::clone(&reduce_barrier),
            sig_slots: Arc::clone(&sig_slots),
            gather_slots: Arc::clone(&gather_slots),
            coll_barrier: Arc::clone(&coll_barrier),
        })
        .collect();
    // drop the original senders so channels close when the world ends
    drop(senders);

    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(nranks);
        for (rank, mut comm) in comms.drain(..).enumerate() {
            let f = &f;
            handles.push(scope.spawn(move || f(rank, &mut comm)));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("rank thread panicked"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_pass() {
        let results = run_world(4, |rank, comm| {
            let next = (rank + 1) % 4;
            let prev = (rank + 3) % 4;
            comm.send(next, 7, vec![rank as f32]);
            let got: Vec<f32> = comm.recv(prev, 7);
            got[0] as usize
        });
        assert_eq!(results, vec![3, 0, 1, 2]);
    }

    #[test]
    fn tags_disambiguate() {
        let results = run_world(2, |rank, comm| {
            let other = 1 - rank;
            comm.send(other, 1, vec![10.0 + rank as f32]);
            comm.send(other, 2, vec![20.0 + rank as f32]);
            // receive in the opposite order to exercise the pending queue
            let b: Vec<f32> = comm.recv(other, 2);
            let a: Vec<f32> = comm.recv(other, 1);
            (a[0], b[0])
        });
        assert_eq!(results[0], (11.0, 21.0));
        assert_eq!(results[1], (10.0, 20.0));
    }

    #[test]
    fn self_send() {
        // the paper enforces communication with the self process
        let results = run_world(1, |_, comm| {
            comm.send(0, 3, vec![1.0f32, 2.0]);
            comm.recv::<f32>(0, 3)
        });
        assert_eq!(results[0], vec![1.0, 2.0]);
    }

    #[test]
    fn allreduce() {
        let results = run_world(3, |rank, comm| {
            let a = comm.allreduce_sum(rank as f64 + 1.0);
            let b = comm.allreduce_sum(rank as f64 * 10.0);
            (a, b)
        });
        for (a, b) in results {
            assert_eq!(a, 6.0);
            assert_eq!(b, 30.0);
        }
    }

    #[test]
    fn wire_sig_roundtrip_and_decode() {
        let sig = wire_sig::<f32>(3, &[true, false, true]);
        assert_eq!(sig & 0xffff_ffff, 0b101);
        assert_eq!((sig >> 32) & 0xfff, 3);
        assert_eq!(sig >> 44, 1);
        let s = decode_wire_sig(sig);
        assert!(s.contains("f32") && s.contains("nrhs 3") && s.contains("101"), "{s}");
        let sig64 = wire_sig::<f64>(2, &[true, true]);
        assert!(decode_wire_sig(sig64).contains("f64"));
        assert_ne!(sig, sig64);
    }

    #[test]
    fn wire_format_handshake_agrees_and_disagrees() {
        // matching formats: every rank gets Ok
        let results = run_world(3, |_, comm| {
            validate_wire_format::<f32>(comm, 2, &[true, false]).is_ok()
        });
        assert!(results.iter().all(|&ok| ok));

        // mask desync: every rank gets a structured error naming ranks
        let results = run_world(2, |rank, comm| {
            let active = if rank == 0 { [true, true] } else { [true, false] };
            validate_wire_format::<f32>(comm, 2, &active).unwrap_err().to_string()
        });
        for msg in &results {
            assert!(msg.contains("rank 0") && msg.contains("rank 1"), "{msg}");
            assert!(msg.contains("before any payload was sent"), "{msg}");
        }

        // precision desync: the decoded error names both precisions
        let results = run_world(2, |rank, comm| {
            if rank == 0 {
                validate_wire_format::<f32>(comm, 1, &[true]).unwrap_err().to_string()
            } else {
                validate_wire_format::<f64>(comm, 1, &[true]).unwrap_err().to_string()
            }
        });
        assert!(results[0].contains("f32") && results[0].contains("f64"));
    }

    #[test]
    fn oversized_batch_is_structured_error_not_a_hang() {
        // every rank over the cap gets Err; none deadlocks at the barrier
        let results = run_world(2, |_, comm| {
            let active = vec![true; 40];
            validate_wire_format::<f32>(comm, 40, &active).unwrap_err().to_string()
        });
        for m in &results {
            assert!(m.contains("at most 32") && m.contains("got nrhs 40"), "{m}");
        }
        // one oversized rank + one valid rank: the valid rank sees a
        // mismatch (sentinel sig), the oversized one its overflow error
        let results = run_world(2, |rank, comm| {
            if rank == 0 {
                validate_wire_format::<f32>(comm, 2, &[true, true])
                    .unwrap_err()
                    .to_string()
            } else {
                validate_wire_format::<f32>(comm, 40, &vec![true; 40])
                    .unwrap_err()
                    .to_string()
            }
        });
        assert!(results[0].contains("mismatch"), "{}", results[0]);
        assert!(results[1].contains("at most 32"), "{}", results[1]);
    }

    #[test]
    fn allgather_returns_rank_ordered_vectors() {
        let results = run_world(3, |rank, comm| {
            let mine = vec![rank as f64, 10.0 * rank as f64];
            let all = comm.allgather_f64(&mine);
            // a second round must not see stale slots
            let all2 = comm.allgather_f64(&[100.0 + rank as f64]);
            (all, all2)
        });
        for (all, all2) in results {
            for r in 0..3 {
                assert_eq!(all[r], vec![r as f64, 10.0 * r as f64]);
                assert_eq!(all2[r], vec![100.0 + r as f64]);
            }
        }
    }

    #[test]
    fn allreduce_any_ors_flags() {
        let results = run_world(3, |rank, comm| {
            let a = comm.allreduce_any(rank == 1);
            let b = comm.allreduce_any(false);
            (a, b)
        });
        for (a, b) in results {
            assert!(a);
            assert!(!b);
        }
    }

    #[test]
    fn same_tag_ordering_preserved() {
        let results = run_world(2, |rank, comm| {
            if rank == 0 {
                comm.send(1, 5, vec![1.0f32]);
                comm.send(1, 5, vec![2.0f32]);
                vec![]
            } else {
                let a: Vec<f32> = comm.recv(0, 5);
                let b: Vec<f32> = comm.recv(0, 5);
                vec![a[0], b[0]]
            }
        });
        assert_eq!(results[1], vec![1.0, 2.0]);
    }
}
