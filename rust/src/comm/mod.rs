//! Communication layer: simulated-MPI rank world, halo-exchange plans and
//! kernels (EO1 pack / EO2 unpack), load balancing, field decomposition,
//! and the TofuD network model for weak-scaling projection.

pub mod balance;
pub mod decompose;
pub mod faults;
pub mod halo;
pub mod netmodel;
pub mod pack;
pub mod tags;
pub mod unpack;
pub mod world;

pub use faults::{FaultKind, FaultPlan};
pub use halo::HaloPlans;
pub use unpack::RecvBuffers;
pub use world::{
    decode_wire_sig, run_world, run_world_cfg, validate_wire_format, wire_sig, Comm,
    CommError, CommScalar, CommStats, Payload, WorldOpts, MAX_WIRE_RHS,
};
