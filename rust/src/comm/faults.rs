//! Deterministic fault injection for the simulated MPI world.
//!
//! A [`FaultPlan`] is parsed from a spec string (config `[faults]` or
//! `lqcd solve --inject-faults <spec>`) and consulted by the transport
//! ([`crate::comm::world`]) on every send and by the distributed
//! operators once per solver iteration. Every trigger decision is a pure
//! function of `(seed, rank, tag, sequence)` — re-running the same spec
//! on the same world replays the identical fault schedule, which is what
//! makes the fault-matrix tests and the CI chaos smoke reproducible.
//!
//! Spec grammar (semicolon-separated rules):
//!
//! ```text
//! spec  := rule (';' rule)*
//! rule  := kind (':' key '=' value (',' key '=' value)*)?
//! kind  := drop | delay | corrupt | sdc | duplicate | truncate
//!        | stall | kill
//! key   := seed | rank | tag | nth | count | ms | iter
//! ```
//!
//! Message kinds (`drop`..`truncate`) act on point-to-point sends whose
//! sender `rank` / `tag` match the rule's filters (unset = any); the
//! rule fires on the `nth` matching send (1-based, per sender) and the
//! following `count - 1` sends. When `nth` is not given it is derived
//! from `seed`, so `drop:seed=7` is a complete reproducible schedule.
//! Rank kinds (`stall`, `kill`) act once, on the victim rank (explicit
//! `rank`, else derived from `seed`) at solver iteration `iter`
//! (explicit, else derived from `seed`).
//!
//! What each kind does to the wire (see `world::Comm::send`):
//!
//! | kind      | effect                                | detected by        |
//! |-----------|---------------------------------------|--------------------|
//! | drop      | payload never posted                  | recv deadline      |
//! | delay     | sender sleeps `ms` before posting     | (self-heals)       |
//! | corrupt   | bit-flips payload, checksum pristine  | checksum mismatch  |
//! | sdc       | NaN payload, checksum *recomputed*    | solver health guard|
//! | duplicate | payload posted twice                  | stale sequence no. |
//! | truncate  | half the payload, checksum pristine   | checksum mismatch  |
//! | stall     | victim sleeps `ms` at iteration `iter`| (self-heals)       |
//! | kill      | victim's comm poisons itself at `iter`| peer recv deadlines|

use std::fmt;

/// One injected fault class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    Drop,
    Delay,
    Corrupt,
    Sdc,
    Duplicate,
    Truncate,
    Stall,
    Kill,
}

impl FaultKind {
    fn parse(s: &str) -> Option<FaultKind> {
        Some(match s {
            "drop" => FaultKind::Drop,
            "delay" => FaultKind::Delay,
            "corrupt" => FaultKind::Corrupt,
            "sdc" => FaultKind::Sdc,
            "duplicate" => FaultKind::Duplicate,
            "truncate" => FaultKind::Truncate,
            "stall" => FaultKind::Stall,
            "kill" => FaultKind::Kill,
            _ => return None,
        })
    }

    fn index(self) -> u64 {
        match self {
            FaultKind::Drop => 0,
            FaultKind::Delay => 1,
            FaultKind::Corrupt => 2,
            FaultKind::Sdc => 3,
            FaultKind::Duplicate => 4,
            FaultKind::Truncate => 5,
            FaultKind::Stall => 6,
            FaultKind::Kill => 7,
        }
    }

    /// Message faults hit individual sends; rank faults hit a rank at a
    /// solver iteration.
    pub fn is_message_fault(self) -> bool {
        !matches!(self, FaultKind::Stall | FaultKind::Kill)
    }

    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Drop => "drop",
            FaultKind::Delay => "delay",
            FaultKind::Corrupt => "corrupt",
            FaultKind::Sdc => "sdc",
            FaultKind::Duplicate => "duplicate",
            FaultKind::Truncate => "truncate",
            FaultKind::Stall => "stall",
            FaultKind::Kill => "kill",
        }
    }
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One parsed rule of a fault plan, with every seed-derived field
/// already resolved (except the kill/stall victim rank, which needs the
/// world size — see [`FaultRule::victim`]).
#[derive(Clone, Debug)]
pub struct FaultRule {
    pub kind: FaultKind,
    pub seed: u64,
    /// message faults: sender-rank filter; rank faults: explicit victim
    pub rank: Option<usize>,
    /// message faults: tag filter (unset = any tag)
    pub tag: Option<u64>,
    /// 1-based index of the first matching send the rule fires on
    pub nth: u64,
    /// how many consecutive matching sends are affected
    pub count: u64,
    /// delay/stall duration in milliseconds
    pub ms: u64,
    /// stall/kill: 0-based solver iteration the rule fires at
    pub iter: usize,
}

impl FaultRule {
    /// The rank a stall/kill rule hits in a world of `nranks`.
    pub fn victim(&self, nranks: usize) -> usize {
        self.rank.unwrap_or(splitmix64(self.seed) as usize % nranks)
    }
}

/// What the transport should do with one particular send.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MessageAction {
    Deliver,
    Drop,
    Delay(u64),
    Corrupt,
    Sdc,
    Duplicate,
    Truncate,
}

/// What a rank should do at one particular solver iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IterAction {
    None,
    Stall(u64),
    Kill,
}

/// Per-communicator rule-match counters. Each rank owns its own state,
/// and a rank's send sequence is deterministic, so the schedule is too.
#[derive(Clone, Debug, Default)]
pub struct FaultState {
    counters: Vec<u64>,
    /// `(rule index, tag, 1-based matching-send hit)` for every trigger
    /// that actually landed in its firing window — the replay tests
    /// compare this log across a checkpoint/restart boundary.
    fired: Vec<(usize, u64, u64)>,
}

impl FaultState {
    /// The per-rule matching-send cursors. Checkpointing these (and
    /// restoring via [`FaultState::restore_cursors`]) is what lets a
    /// resumed solve fire the *remaining* triggers of a seeded plan at
    /// the same `(rank, tag, sequence)` points as the uninterrupted run.
    pub fn cursors(&self) -> Vec<u64> {
        self.counters.clone()
    }

    /// Restore cursors saved by [`FaultState::cursors`]. Extra or
    /// missing entries (plan changed between runs) are ignored
    /// positionally rather than erroring — the plan text is the
    /// authority on rule count.
    pub fn restore_cursors(&mut self, saved: &[u64]) {
        for (c, &s) in self.counters.iter_mut().zip(saved) {
            *c = s;
        }
    }

    /// Triggers that fired so far, in order.
    pub fn fired(&self) -> &[(usize, u64, u64)] {
        &self.fired
    }
}

/// A complete, reproducible fault schedule.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub rules: Vec<FaultRule>,
    /// the original spec string, echoed in diagnostics
    pub spec: String,
}

impl FaultPlan {
    /// The empty plan: no faults, transport overhead limited to the wire
    /// header (the retransmit store stays disabled).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    /// Parse a spec string (see module docs for the grammar).
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut rules = Vec::new();
        for raw in spec.split(';') {
            let raw = raw.trim();
            if raw.is_empty() {
                continue;
            }
            let (head, args) = match raw.split_once(':') {
                Some((h, a)) => (h.trim(), a),
                None => (raw, ""),
            };
            let kind = FaultKind::parse(head).ok_or_else(|| {
                format!(
                    "unknown fault kind {head:?} (expected drop, delay, corrupt, \
                     sdc, duplicate, truncate, stall or kill)"
                )
            })?;
            let mut seed = 1u64;
            let mut rank = None;
            let mut tag = None;
            let mut nth = None;
            let mut count = 1u64;
            let mut ms = None;
            let mut iter = None;
            for kv in args.split(',') {
                let kv = kv.trim();
                if kv.is_empty() {
                    continue;
                }
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| format!("fault option {kv:?} is not key=value"))?;
                let (k, v) = (k.trim(), v.trim());
                let num = |name: &str| -> Result<u64, String> {
                    v.parse::<u64>()
                        .map_err(|_| format!("fault option {name}={v:?} is not a number"))
                };
                match k {
                    "seed" => seed = num("seed")?,
                    "rank" => rank = Some(num("rank")? as usize),
                    "tag" => tag = Some(num("tag")?),
                    "nth" => {
                        let n = num("nth")?;
                        if n == 0 {
                            return Err("fault option nth is 1-based (got 0)".into());
                        }
                        nth = Some(n);
                    }
                    "count" => {
                        count = num("count")?;
                        if count == 0 {
                            return Err("fault option count must be >= 1".into());
                        }
                    }
                    "ms" => ms = Some(num("ms")?),
                    "iter" => iter = Some(num("iter")? as usize),
                    _ => {
                        return Err(format!(
                            "unknown fault option {k:?} (expected seed, rank, tag, \
                             nth, count, ms or iter)"
                        ))
                    }
                }
            }
            let idx = rules.len() as u64;
            // seed-derived defaults: which send / iteration the rule hits
            let nth = nth.unwrap_or(1 + splitmix64(seed ^ (kind.index() << 32) ^ idx) % 4);
            let iter =
                iter.unwrap_or(1 + (splitmix64(seed ^ kind.index()) % 5) as usize);
            let ms = ms.unwrap_or(match kind {
                FaultKind::Delay => 40,
                FaultKind::Stall => 100,
                _ => 0,
            });
            rules.push(FaultRule { kind, seed, rank, tag, nth, count, ms, iter });
        }
        Ok(FaultPlan { rules, spec: spec.to_string() })
    }

    /// Fresh match-counter state for one communicator.
    pub fn new_state(&self) -> FaultState {
        FaultState { counters: vec![0; self.rules.len()], fired: Vec::new() }
    }

    /// The same plan with every `kill` rule defused (its trigger
    /// iteration pushed past any reachable solve). A resume relaunch
    /// uses this: the kill already did its damage in the previous
    /// incarnation, and replaying it would just murder the world again
    /// at the same iteration. Rules are defused in place rather than
    /// removed so rule indices — and therefore checkpointed fault
    /// cursors — stay aligned.
    pub fn without_kills(&self) -> FaultPlan {
        let mut plan = self.clone();
        for r in &mut plan.rules {
            if r.kind == FaultKind::Kill {
                r.iter = usize::MAX;
            }
        }
        plan
    }

    /// Decide the fate of one send. `from` is the sending rank (the
    /// rule's `rank` filter), `tag`/`seq` identify the message. Counters
    /// advance per rule per sender, so the decision is a pure function
    /// of the send sequence.
    pub fn message_action(
        &self,
        state: &mut FaultState,
        from: usize,
        tag: u64,
        _seq: u64,
    ) -> MessageAction {
        let mut action = MessageAction::Deliver;
        for (i, rule) in self.rules.iter().enumerate() {
            if !rule.kind.is_message_fault() {
                continue;
            }
            if rule.rank.is_some_and(|r| r != from) {
                continue;
            }
            if rule.tag.is_some_and(|t| t != tag) {
                continue;
            }
            let hit = state.counters[i] + 1; // 1-based matching-send index
            state.counters[i] = hit;
            if hit >= rule.nth && hit < rule.nth + rule.count {
                state.fired.push((i, tag, hit));
            }
            if action == MessageAction::Deliver
                && hit >= rule.nth
                && hit < rule.nth + rule.count
            {
                action = match rule.kind {
                    FaultKind::Drop => MessageAction::Drop,
                    FaultKind::Delay => MessageAction::Delay(rule.ms),
                    FaultKind::Corrupt => MessageAction::Corrupt,
                    FaultKind::Sdc => MessageAction::Sdc,
                    FaultKind::Duplicate => MessageAction::Duplicate,
                    FaultKind::Truncate => MessageAction::Truncate,
                    FaultKind::Stall | FaultKind::Kill => unreachable!(),
                };
            }
        }
        action
    }

    /// Decide what `rank` (of `nranks`) does at solver iteration `iter`.
    pub fn iteration_action(&self, rank: usize, nranks: usize, iter: usize) -> IterAction {
        for rule in &self.rules {
            if rule.kind.is_message_fault() {
                continue;
            }
            if rule.victim(nranks) == rank && rule.iter == iter {
                return match rule.kind {
                    FaultKind::Stall => IterAction::Stall(rule.ms),
                    FaultKind::Kill => IterAction::Kill,
                    _ => unreachable!(),
                };
            }
        }
        IterAction::None
    }
}

/// SplitMix64: the one-shot mixer behind every seed-derived default.
pub fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kinds_and_options() {
        let p = FaultPlan::parse("drop:seed=7;corrupt:rank=1,tag=9,nth=2,count=3;kill:iter=4")
            .unwrap();
        assert_eq!(p.rules.len(), 3);
        assert_eq!(p.rules[0].kind, FaultKind::Drop);
        assert_eq!(p.rules[0].seed, 7);
        assert!(p.rules[0].nth >= 1 && p.rules[0].nth <= 4, "{}", p.rules[0].nth);
        assert_eq!(p.rules[1].kind, FaultKind::Corrupt);
        assert_eq!(p.rules[1].rank, Some(1));
        assert_eq!(p.rules[1].tag, Some(9));
        assert_eq!(p.rules[1].nth, 2);
        assert_eq!(p.rules[1].count, 3);
        assert_eq!(p.rules[2].kind, FaultKind::Kill);
        assert_eq!(p.rules[2].iter, 4);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("explode").is_err());
        assert!(FaultPlan::parse("drop:frequency=2").is_err());
        assert!(FaultPlan::parse("drop:nth=zero").is_err());
        assert!(FaultPlan::parse("drop:nth=0").is_err());
        assert!(FaultPlan::parse("drop:nth").is_err());
    }

    #[test]
    fn empty_spec_is_empty_plan() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::none().is_empty());
    }

    #[test]
    fn schedule_is_deterministic_in_seed() {
        let a = FaultPlan::parse("drop:seed=7").unwrap();
        let b = FaultPlan::parse("drop:seed=7").unwrap();
        let c = FaultPlan::parse("drop:seed=8").unwrap();
        assert_eq!(a.rules[0].nth, b.rules[0].nth);
        // different seeds usually pick different sends; at minimum the
        // derivation must be a function of the seed alone
        let _ = c.rules[0].nth;
        let mut st = a.new_state();
        let fired: Vec<bool> = (0..8)
            .map(|s| a.message_action(&mut st, 0, 3, s) == MessageAction::Drop)
            .collect();
        let mut st2 = b.new_state();
        let fired2: Vec<bool> = (0..8)
            .map(|s| b.message_action(&mut st2, 0, 3, s) == MessageAction::Drop)
            .collect();
        assert_eq!(fired, fired2);
        assert_eq!(fired.iter().filter(|&&f| f).count(), 1);
    }

    #[test]
    fn filters_gate_the_rule() {
        let p = FaultPlan::parse("drop:rank=1,tag=5,nth=1").unwrap();
        let mut st = p.new_state();
        // wrong rank, wrong tag: delivered, counters untouched
        assert_eq!(p.message_action(&mut st, 0, 5, 0), MessageAction::Deliver);
        assert_eq!(p.message_action(&mut st, 1, 4, 0), MessageAction::Deliver);
        // first matching send fires
        assert_eq!(p.message_action(&mut st, 1, 5, 0), MessageAction::Drop);
        // only once (count=1)
        assert_eq!(p.message_action(&mut st, 1, 5, 1), MessageAction::Deliver);
    }

    #[test]
    fn rank_faults_pick_one_victim_and_iteration() {
        let p = FaultPlan::parse("kill:rank=1,iter=3").unwrap();
        assert_eq!(p.iteration_action(0, 2, 3), IterAction::None);
        assert_eq!(p.iteration_action(1, 2, 2), IterAction::None);
        assert_eq!(p.iteration_action(1, 2, 3), IterAction::Kill);
        // derived victim stays inside the world
        let q = FaultPlan::parse("stall:seed=12345").unwrap();
        let v = q.rules[0].victim(4);
        assert!(v < 4);
        assert_eq!(q.iteration_action(v, 4, q.rules[0].iter), IterAction::Stall(100));
    }
}
