//! TofuD network model + discrete-event weak-scaling simulator.
//!
//! Fugaku's Tofu interconnect D gives each node 28 Gbps x 2 lanes x 10
//! ports (paper §3.1); per neighbor link the effective payload bandwidth
//! is ~6.8 GB/s, with ~1 us put latency. The paper's rank maps guarantee
//! every halo exchange is nearest-neighbor (within the node between CMGs,
//! or one hop on the 6D mesh-torus), so per-node communication cost is
//! *independent of the node count* — that is why Fig. 10 is flat.
//!
//! This module projects measured single-node kernel times onto a
//! multi-node machine: a discrete-event simulation where each rank's
//! dslash is (EO1 -> post sends) || bulk -> wait(halos) -> EO2, with wire
//! times from this model. The *compute* times are real measurements from
//! the native kernels on this host; only the wire is modeled.

/// TofuD-like link parameters (per neighbor exchange).
#[derive(Clone, Copy, Debug)]
pub struct NetModel {
    /// effective point-to-point payload bandwidth (bytes/s)
    pub bandwidth: f64,
    /// one-way latency (s)
    pub latency: f64,
    /// intra-node (CMG-to-CMG) bandwidth for same-node neighbors (bytes/s)
    pub intra_bandwidth: f64,
    pub intra_latency: f64,
}

impl NetModel {
    /// TofuD injection: 6.8 GB/s per port, ~1 us latency; intra-node
    /// CMG-to-CMG via the ring bus, ~115 GB/s class, ~0.2 us.
    pub fn tofu_d() -> NetModel {
        NetModel {
            bandwidth: 6.8e9,
            latency: 1.0e-6,
            intra_bandwidth: 115.0e9,
            intra_latency: 0.2e-6,
        }
    }

    /// Wire time of one message of `bytes`, intra- or inter-node.
    pub fn transfer_time(&self, bytes: usize, intra_node: bool) -> f64 {
        if intra_node {
            self.intra_latency + bytes as f64 / self.intra_bandwidth
        } else {
            self.latency + bytes as f64 / self.bandwidth
        }
    }
}

/// Halo traffic of one (batched) distributed hopping application:
/// message count and wire bytes, per rank.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HaloTraffic {
    /// point-to-point messages posted (2 per communicated direction:
    /// upward + downward export) — independent of the batch width
    pub messages: u64,
    /// payload bytes across all messages: 12 reals per face site per
    /// *active* RHS
    pub bytes: u64,
}

/// Traffic model of one batched hopping: each communicated direction
/// posts exactly TWO messages whatever `nact` is (that is the batching
/// win — N right-hand sides ride the same latency), while the payload
/// carries `face * nact * 12` reals per orientation. Masked (converged)
/// RHS cost zero bytes.
pub fn batched_hopping_traffic(
    face_count: [usize; 4],
    comm: [bool; 4],
    nact: usize,
    elem_bytes: usize,
) -> HaloTraffic {
    let mut messages = 0u64;
    let mut bytes = 0u64;
    for d in 0..4 {
        if comm[d] {
            messages += 2;
            bytes += (2 * face_count[d] * nact * crate::comm::halo::HALF_SPINOR_F32
                * elem_bytes) as u64;
        }
    }
    HaloTraffic { messages, bytes }
}

/// Wire bytes per (local site, RHS) of one batched hopping: constant in
/// the batch width — batching amortizes the message *count* (latency)
/// and lets the memory-side gauge stream amortize, it does not shrink
/// the per-RHS payload. This is why batching does NOT help a
/// latency-free, bandwidth-bound wire; see ARCHITECTURE.md.
pub fn halo_bytes_per_site_rhs(t: HaloTraffic, nsites: usize, nact: usize) -> f64 {
    if nact == 0 {
        return 0.0;
    }
    t.bytes as f64 / (nsites * nact) as f64
}

/// Per-rank measured compute times feeding the simulation (seconds).
#[derive(Clone, Copy, Debug)]
pub struct RankCompute {
    pub eo1: f64,
    pub bulk: f64,
    pub eo2: f64,
}

/// Message sizes of one hopping application (bytes per direction).
#[derive(Clone, Copy, Debug)]
pub struct HaloBytes {
    pub per_dir: [usize; 4],
    /// is the neighbor in this direction on the same node?
    pub intra: [bool; 4],
}

/// Simulated wall-clock of one distributed hopping application under the
/// model: every rank runs EO1, posts both sends per direction, overlaps
/// bulk with the wire, then waits for the slowest halo and runs EO2.
///
/// All ranks are identical by symmetry of the decomposition, so the
/// simulation is per-rank with neighbor times equal to own times (SPMD
/// steady state) — the paper's setup (uniform local volume, neighbor-only
/// rank maps) satisfies this exactly.
pub fn hopping_wallclock(c: RankCompute, h: HaloBytes, net: &NetModel) -> f64 {
    // sends are posted after EO1; the wire runs concurrently with bulk
    let mut slowest_arrival: f64 = 0.0;
    for dir in 0..4 {
        if h.per_dir[dir] == 0 {
            continue;
        }
        // both orientations, posted back-to-back after EO1
        let wire = net.transfer_time(h.per_dir[dir], h.intra[dir]);
        slowest_arrival = slowest_arrival.max(c.eo1 + wire);
    }
    let halos_ready = slowest_arrival;
    let bulk_done = c.eo1 + c.bulk;
    bulk_done.max(halos_ready) + c.eo2
}

/// Weak-scaling projection: per-node sustained GFlops vs node count.
///
/// `flops_per_rank` is the flop count of one hopping application on one
/// rank. With neighbor-only communication the simulated wallclock is
/// node-count independent; node counts only enter through which neighbors
/// stay intra-node (the 4-ranks-per-node [2,2,1,1] CMG placement keeps x/y
/// neighbors on-node for single-node runs, and off-node otherwise).
pub fn weak_scaling_gflops_per_node(
    nodes: &[usize],
    ranks_per_node: usize,
    c: RankCompute,
    bytes_per_dir: [usize; 4],
    flops_per_rank: u64,
    net: &NetModel,
) -> Vec<(usize, f64)> {
    nodes
        .iter()
        .map(|&n| {
            // single node: all neighbors intra; multi-node: the directions
            // split across nodes go off-node. The paper's rank maps place
            // 4 ranks/node as a [1,1,2,2] block: z/t neighbors on-node
            // until the grid grows past the node, x/y depend on the global
            // grid. Conservatively: on one node everything is intra; on
            // many nodes z/t stay intra (CMG pairs) and x/y go inter.
            let intra = if n == 1 {
                [true; 4]
            } else {
                [false, false, true, true]
            };
            let wall = hopping_wallclock(
                c,
                HaloBytes {
                    per_dir: bytes_per_dir,
                    intra,
                },
                net,
            );
            let gflops_rank = flops_per_rank as f64 / wall / 1e9;
            (n, gflops_rank * ranks_per_node as f64)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batched_traffic_messages_independent_of_nrhs() {
        let faces = [8usize, 32, 16, 16];
        let comm = [true, true, true, false];
        let one = batched_hopping_traffic(faces, comm, 1, 4);
        let four = batched_hopping_traffic(faces, comm, 4, 4);
        // message count: 2 per live direction, whatever the batch width
        assert_eq!(one.messages, 6);
        assert_eq!(four.messages, one.messages);
        // payload: linear in active RHS, zero for masked ones
        assert_eq!(four.bytes, 4 * one.bytes);
        assert_eq!(one.bytes, (2 * (8 + 32 + 16) * 12 * 4) as u64);
        let none = batched_hopping_traffic(faces, comm, 0, 4);
        assert_eq!(none.bytes, 0);
        // f64 wire doubles the bytes, not the messages
        let wide = batched_hopping_traffic(faces, comm, 1, 8);
        assert_eq!(wide.bytes, 2 * one.bytes);
        assert_eq!(wide.messages, one.messages);
    }

    #[test]
    fn halo_bytes_per_site_rhs_constant_in_nrhs() {
        let faces = [8usize, 32, 16, 16];
        let comm = [true; 4];
        let nsites = 512;
        let a = halo_bytes_per_site_rhs(batched_hopping_traffic(faces, comm, 1, 4), nsites, 1);
        let b = halo_bytes_per_site_rhs(batched_hopping_traffic(faces, comm, 4, 4), nsites, 4);
        assert!((a - b).abs() < 1e-12, "wire bytes/site/RHS must not depend on nrhs");
        assert_eq!(halo_bytes_per_site_rhs(batched_hopping_traffic(faces, comm, 0, 4), nsites, 0), 0.0);
    }

    #[test]
    fn transfer_time_monotone_in_size() {
        let net = NetModel::tofu_d();
        assert!(net.transfer_time(1 << 20, false) > net.transfer_time(1 << 10, false));
        assert!(net.transfer_time(1 << 20, true) < net.transfer_time(1 << 20, false));
    }

    #[test]
    fn overlap_hides_fast_wire() {
        let net = NetModel::tofu_d();
        let c = RankCompute {
            eo1: 10e-6,
            bulk: 100e-6,
            eo2: 20e-6,
        };
        let h = HaloBytes {
            per_dir: [1000, 1000, 1000, 1000],
            intra: [false; 4],
        };
        // wire (~1.1 us) finishes well inside the 100 us bulk
        let wall = hopping_wallclock(c, h, &net);
        assert!((wall - (10e-6 + 100e-6 + 20e-6)).abs() < 1e-9);
    }

    #[test]
    fn slow_wire_exposes_wait() {
        let net = NetModel {
            bandwidth: 1e6, // pathologically slow
            latency: 1e-3,
            intra_bandwidth: 1e6,
            intra_latency: 1e-3,
        };
        let c = RankCompute {
            eo1: 10e-6,
            bulk: 100e-6,
            eo2: 20e-6,
        };
        let h = HaloBytes {
            per_dir: [100_000, 0, 0, 0],
            intra: [false; 4],
        };
        let wall = hopping_wallclock(c, h, &net);
        assert!(wall > 0.1, "wire-bound case must dominate ({wall})");
    }

    #[test]
    fn weak_scaling_is_flat_for_neighbor_comm() {
        let net = NetModel::tofu_d();
        let c = RankCompute {
            eo1: 10e-6,
            bulk: 150e-6,
            eo2: 25e-6,
        };
        let series = weak_scaling_gflops_per_node(
            &[1, 2, 8, 64, 512],
            4,
            c,
            [50_000, 50_000, 80_000, 80_000],
            1368 * 8192,
            &net,
        );
        let first = series[1].1; // multi-node baseline
        for &(n, g) in &series[1..] {
            assert!(
                (g - first).abs() / first < 1e-9,
                "per-node perf must be n-independent beyond 1 node (n={n})"
            );
        }
        // single node (all intra) is at least as fast
        assert!(series[0].1 >= first);
    }
}
