//! Static load balancing for the EO2 unpack loop — the paper's proposed
//! future work (§4.1): "the number of operations on each boundary lattice
//! site can be statically evaluated in advance. In the future version, we
//! plan to improve the load balance of the EO2 kernel based on this
//! empirical information."
//!
//! [`balanced_chunks`] partitions the flat site range into `n` contiguous
//! chunks of (approximately) equal *cost* using the per-site operation
//! count from [`super::unpack::site_cost`], instead of equal site count.

use super::halo::HaloPlans;
use super::unpack::site_cost;

/// Equal-count partition (the paper's current scheme; imbalanced in EO2).
pub fn uniform_chunks(nsites: usize, nthreads: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(nthreads);
    let base = nsites / nthreads;
    let rem = nsites % nthreads;
    let mut begin = 0;
    for tid in 0..nthreads {
        let len = base + usize::from(tid < rem);
        out.push((begin, begin + len));
        begin += len;
    }
    out
}

/// Cost-weighted partition of the EO2 site loop: contiguous chunks whose
/// per-chunk cost is as even as the site granularity allows.
pub fn balanced_chunks(plans: &HaloPlans, nthreads: usize) -> Vec<(usize, usize)> {
    balanced_chunks_granular(plans, nthreads, 1)
}

/// [`balanced_chunks`] with a chunk-boundary granularity: every chunk
/// boundary (except the final `nsites`) is rounded up to a multiple of
/// `granularity` sites. Coarser boundaries trade a little balance for
/// unpack loops that start on tile-aligned offsets — which of the two
/// wins is machine-dependent, so `lqcd tune` sweeps it.
pub fn balanced_chunks_granular(
    plans: &HaloPlans,
    nthreads: usize,
    granularity: usize,
) -> Vec<(usize, usize)> {
    let nsites = plans.nsites;
    let gran = granularity.max(1);
    let costs: Vec<u64> = (0..nsites).map(|f| site_cost(plans, f)).collect();
    let total: u64 = costs.iter().sum();
    if total == 0 {
        return uniform_chunks(nsites, nthreads);
    }
    let mut out = Vec::with_capacity(nthreads);
    let mut begin = 0usize;
    let mut acc = 0u64;
    let mut consumed = 0u64;
    for tid in 0..nthreads {
        // remaining cost spread over remaining threads
        let want = (total - consumed) / (nthreads - tid) as u64;
        let mut end = begin;
        if tid == nthreads - 1 {
            end = nsites;
            acc = total - consumed;
        } else {
            while end < nsites && (acc < want || end == begin) {
                acc += costs[end];
                end += 1;
            }
            if end < nsites && end % gran != 0 {
                let aligned = (end / gran + 1) * gran;
                let aligned = aligned.min(nsites);
                acc += costs[end..aligned].iter().sum::<u64>();
                end = aligned;
            }
        }
        out.push((begin, end));
        consumed += acc;
        begin = end;
        acc = 0;
    }
    out
}

/// Cost of a chunk under the plan (for tests and the Fig. 9 harness).
pub fn chunk_cost(plans: &HaloPlans, chunk: (usize, usize)) -> u64 {
    (chunk.0..chunk.1).map(|f| site_cost(plans, f)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::{Geometry, LatticeDims, Parity, Tiling};

    fn plans() -> HaloPlans {
        let geom = Geometry::single_rank(
            LatticeDims::new(8, 8, 4, 8).unwrap(),
            Tiling::new(2, 2).unwrap(),
        )
        .unwrap();
        HaloPlans::new(&geom, Parity::Odd, [true; 4])
    }

    #[test]
    fn uniform_covers_range() {
        let chunks = uniform_chunks(103, 12);
        assert_eq!(chunks.len(), 12);
        assert_eq!(chunks[0].0, 0);
        assert_eq!(chunks.last().unwrap().1, 103);
        for w in chunks.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
    }

    #[test]
    fn balanced_covers_range_and_reduces_imbalance() {
        let p = plans();
        let n = 12;
        let uni = uniform_chunks(p.nsites, n);
        let bal = balanced_chunks(&p, n);
        assert_eq!(bal.len(), n);
        assert_eq!(bal[0].0, 0);
        assert_eq!(bal.last().unwrap().1, p.nsites);
        for w in bal.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
        let imbalance = |chunks: &[(usize, usize)]| {
            let costs: Vec<u64> = chunks.iter().map(|&c| chunk_cost(&p, c)).collect();
            let max = *costs.iter().max().unwrap() as f64;
            let mean = costs.iter().sum::<u64>() as f64 / costs.len() as f64;
            max / mean
        };
        let iu = imbalance(&uni);
        let ib = imbalance(&bal);
        assert!(
            iu > 1.5,
            "uniform split should be visibly imbalanced (got {iu:.2})"
        );
        assert!(
            ib < iu * 0.7,
            "balanced split must cut the imbalance: {ib:.2} vs {iu:.2}"
        );
    }

    #[test]
    fn granular_boundaries_are_aligned_and_cover_range() {
        let p = plans();
        for gran in [1usize, 4, 16] {
            let chunks = balanced_chunks_granular(&p, 6, gran);
            assert_eq!(chunks.len(), 6);
            assert_eq!(chunks[0].0, 0);
            assert_eq!(chunks.last().unwrap().1, p.nsites);
            for w in chunks.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
            for &(_, end) in &chunks[..chunks.len() - 1] {
                assert!(
                    end % gran == 0 || end == p.nsites,
                    "boundary {end} not aligned to {gran}"
                );
            }
        }
    }

    #[test]
    fn granularity_one_matches_balanced() {
        let p = plans();
        assert_eq!(balanced_chunks(&p, 8), balanced_chunks_granular(&p, 8, 1));
    }

    #[test]
    fn balanced_degenerates_gracefully() {
        // no comm -> zero cost everywhere -> uniform fallback
        let geom = Geometry::single_rank(
            LatticeDims::new(4, 4, 4, 4).unwrap(),
            Tiling::new(2, 2).unwrap(),
        )
        .unwrap();
        let p = HaloPlans::new(&geom, Parity::Even, [false; 4]);
        let chunks = balanced_chunks(&p, 4);
        assert_eq!(chunks, uniform_chunks(p.nsites, 4));
    }
}
