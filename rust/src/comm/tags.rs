//! Central wire-tag registry: every `Comm::send`/`recv` tag in the tree
//! is minted here, so the namespaces provably cannot collide.
//!
//! The 64-bit tag space is partitioned by bit range:
//!
//! | bits    | namespace       | constructor            | contents                          |
//! |---------|-----------------|------------------------|-----------------------------------|
//! | 0..9    | `Halo`          | [`halo`]               | `parity<<8 \| dir<<1 \| upward`   |
//! | 9..57   | `HaloBatched`   | [`halo_batched`]       | halo bits + `wire_sig << 9`       |
//! | 57..62  | (reserved)      | —                      | zero today; future `lqcd serve`   |
//! | 62      | `Collective`    | [`collective`]         | reserved collective/barrier block |
//! | 63      | `CkptBuddy`     | [`ckpt_buddy`]         | `1<<63 \| checkpoint generation`  |
//!
//! A single-RHS halo tag is also a valid batched tag with `sig == 0`
//! (an empty signature never validates, so the two cannot be confused
//! on the wire). The checkpoint-buddy namespace owns bit 63 alone:
//! every halo/batched/collective tag keeps it clear, which is what lets
//! buddy ring-copy traffic share the transport with live halo exchange
//! during a restore. The invariant linter (`lqcd lint`, rule
//! `tag-registry`) rejects tag construction anywhere else in the tree.

use crate::lattice::Parity;

/// Bits the single-RHS halo tag occupies: parity (1) + dir (3) + up (1),
/// packed as `parity<<8 | dir<<1 | upward` (bit 0 = orientation, bits
/// 1..4 = direction, bit 8 = output parity — the historical wire layout,
/// frozen so old traces stay decodable).
pub const HALO_BITS: u32 = 9;
/// Where the batched halo signature lands.
pub const SIG_SHIFT: u32 = HALO_BITS;
/// Width of `wire_sig`: active mask (32) + nrhs (12) + precision id (4).
pub const SIG_BITS: u32 = 48;
/// Reserved block for collective/barrier traffic (future `lqcd serve`).
pub const NS_COLLECTIVE: u64 = 1 << 62;
/// Checkpoint buddy-exchange namespace flag: bit 63 set, generation in
/// the low bits.
pub const NS_CKPT_BUDDY: u64 = 1 << 63;

// The partition is checked at compile time: the halo bits must fit
// below the signature, the signature below the collective block, and
// both namespace flags must be distinct single bits below nothing.
const _: () = {
    assert!((1u64 << HALO_BITS) - 1 < (1u64 << SIG_SHIFT));
    assert!(SIG_SHIFT + SIG_BITS <= 62);
    assert!(NS_COLLECTIVE < NS_CKPT_BUDDY);
    assert!(NS_COLLECTIVE & NS_CKPT_BUDDY == 0);
};

/// Single-RHS halo-exchange tag: direction, orientation, output parity.
#[inline]
pub fn halo(dir: usize, upward: bool, p_out: Parity) -> u64 {
    debug_assert!(dir < 8);
    ((p_out.index() as u64) << 8) | ((dir as u64) << 1) | u64::from(upward)
}

/// Batched-message tag: the single-RHS halo tag plus the halo wire
/// signature (precision, nrhs, active mask), so a rank that somehow got
/// past the pre-send handshake with a diverged batch shape can never
/// consume a mismatched payload — the tags simply don't match.
#[inline]
pub fn halo_batched(dir: usize, upward: bool, p_out: Parity, sig: u64) -> u64 {
    debug_assert!(sig < (1u64 << SIG_BITS), "wire sig overflows tag space");
    halo(dir, upward, p_out) | (sig << SIG_SHIFT)
}

/// Checkpoint buddy-exchange tag for one committed generation. Disjoint
/// from every halo/handshake tag (bit 63), so ring-copy traffic can
/// share the transport with live solves.
#[inline]
pub fn ckpt_buddy(gen: u64) -> u64 {
    debug_assert!(gen & NS_CKPT_BUDDY == 0, "generation overflows tag space");
    NS_CKPT_BUDDY | gen
}

/// Reserved collective tag block (barrier/reduce traffic for the
/// long-lived `lqcd serve` on the roadmap). Nothing mints these yet;
/// the block exists so the next subsystem extends the registry instead
/// of squatting on free-looking bits.
#[inline]
pub fn collective(kind: u16) -> u64 {
    NS_COLLECTIVE | u64::from(kind)
}

/// Which namespace a tag belongs to (diagnostics and the model checker).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TagNamespace {
    Halo,
    HaloBatched,
    Collective,
    CkptBuddy,
}

/// Classify a wire tag by namespace. Total: every u64 lands somewhere,
/// and the partition ranges cannot overlap by construction.
pub fn namespace(tag: u64) -> TagNamespace {
    if tag & NS_CKPT_BUDDY != 0 {
        TagNamespace::CkptBuddy
    } else if tag & NS_COLLECTIVE != 0 {
        TagNamespace::Collective
    } else if tag >> SIG_SHIFT != 0 {
        TagNamespace::HaloBatched
    } else {
        TagNamespace::Halo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn namespaces_are_disjoint() {
        let h = halo(3, true, Parity::Even);
        let hb = halo_batched(3, true, Parity::Even, 0xF0000_0000_0001);
        let ck = ckpt_buddy(42);
        let co = collective(7);
        assert_eq!(namespace(h), TagNamespace::Halo);
        assert_eq!(namespace(hb), TagNamespace::HaloBatched);
        assert_eq!(namespace(ck), TagNamespace::CkptBuddy);
        assert_eq!(namespace(co), TagNamespace::Collective);
        // pairwise distinct even with colliding low bits
        assert_ne!(h, hb);
        assert_ne!(hb | NS_CKPT_BUDDY, hb);
        assert_eq!(ck & ((1 << SIG_SHIFT) - 1), 42);
    }

    #[test]
    fn halo_tags_injective_over_inputs() {
        let mut seen = std::collections::HashSet::new();
        for dir in 0..4 {
            for &up in &[false, true] {
                for &p in &[Parity::Even, Parity::Odd] {
                    assert!(seen.insert(halo(dir, up, p)));
                }
            }
        }
        assert_eq!(seen.len(), 16);
    }
}
