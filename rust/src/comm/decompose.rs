//! Global <-> per-rank field decomposition.
//!
//! Used by the multi-rank driver tests (a distributed hopping must equal
//! the single-rank periodic operator on the joined field) and by the
//! examples to set up distributed runs from one global configuration.

use crate::algebra::Real;
use crate::field::{FermionField, GaugeField};
use crate::lattice::{
    Dir, EvenOdd, Geometry, Parity, SiteCoord,
};

/// Extract this rank's local fermion field from a global one.
///
/// Both fields hold the same parity. Local extents are all even, so the
/// local parity of a site equals its global parity.
pub fn extract_fermion<R: Real>(
    global: &FermionField<R>,
    _ggeom: &Geometry,
    lgeom: &Geometry,
) -> FermionField<R> {
    let mut local = FermionField::zeros(lgeom);
    let origin = lgeom.origin();
    let sites: Vec<SiteCoord> = local.layout.sites().collect();
    for s in sites {
        let gs = global_site(lgeom, s, origin);
        let v = global.site(gs);
        local.set_site(s, &v);
    }

    local
}

/// Insert a rank's local fermion field into the global one.
pub fn insert_fermion<R: Real>(
    global: &mut FermionField<R>,
    local: &FermionField<R>,
    lgeom: &Geometry,
) {
    let origin = lgeom.origin();
    for s in local.layout.sites().collect::<Vec<_>>() {
        let gs = global_site(lgeom, s, origin);
        let v = local.site(s);
        global.set_site(gs, &v);
    }
}

/// Extract this rank's local gauge field from a global one.
pub fn extract_gauge<R: Real>(global: &GaugeField<R>, lgeom: &Geometry) -> GaugeField<R> {
    let mut local = GaugeField::unit(lgeom);
    let origin = lgeom.origin();
    for p in Parity::BOTH {
        for s in EoLayoutSites::new(lgeom) {
            // local compacted site of parity p -> global lexical coords
            let phi = EvenOdd::row_parity(s.y, s.z, s.t, p);
            let lx = EvenOdd::lexical_x(s.ix, phi);
            let gx = origin[0] + lx;
            let gy = origin[1] + s.y;
            let gz = origin[2] + s.z;
            let gt = origin[3] + s.t;
            for dir in Dir::ALL {
                let u = global.link_at(dir, gx, gy, gz, gt);
                local.set_link(dir, p, s, &u);
            }
        }
    }
    local
}

/// Convert a local compacted site (of one parity) to the global compacted
/// site of the same parity.
fn global_site(_lgeom: &Geometry, s: SiteCoord, origin: [usize; 4]) -> SiteCoord {
    // the compacted x index shifts by origin_x / 2 (origin_x is even)
    debug_assert_eq!(origin[0] % 2, 0);
    SiteCoord {
        t: origin[3] + s.t,
        z: origin[2] + s.z,
        y: origin[1] + s.y,
        ix: origin[0] / 2 + s.ix,
    }
}

/// Iterate local sites (helper; same as layout.sites() but avoids holding
/// a borrow of a temporary layout).
struct EoLayoutSites {
    sites: std::vec::IntoIter<SiteCoord>,
}

impl EoLayoutSites {
    fn new(geom: &Geometry) -> Self {
        let l = crate::lattice::EoLayout::new(geom);
        EoLayoutSites {
            sites: l.sites().collect::<Vec<_>>().into_iter(),
        }
    }
}

impl Iterator for EoLayoutSites {
    type Item = SiteCoord;
    fn next(&mut self) -> Option<SiteCoord> {
        self.sites.next()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::{LatticeDims, ProcGrid, Tiling};
    use crate::util::rng::Rng;

    #[test]
    fn fermion_split_join_roundtrip() {
        let global_dims = LatticeDims::new(8, 4, 4, 8).unwrap();
        let tiling = Tiling::new(2, 2).unwrap();
        let ggeom = Geometry::single_rank(global_dims, tiling).unwrap();
        let grid = ProcGrid([1, 1, 2, 2]);
        let mut rng = Rng::seeded(3);
        let global: FermionField = FermionField::gaussian(&ggeom, &mut rng);

        let mut rebuilt = FermionField::zeros(&ggeom);
        for rank in 0..grid.size() {
            let lgeom = Geometry::for_rank(global_dims, grid, rank, tiling).unwrap();
            let local = extract_fermion(&global, &ggeom, &lgeom);
            insert_fermion(&mut rebuilt, &local, &lgeom);
        }
        assert_eq!(global.data, rebuilt.data);
    }

    #[test]
    fn gauge_extraction_preserves_links() {
        let global_dims = LatticeDims::new(8, 4, 4, 4).unwrap();
        let tiling = Tiling::new(2, 2).unwrap();
        let ggeom = Geometry::single_rank(global_dims, tiling).unwrap();
        let grid = ProcGrid([2, 1, 1, 2]);
        let mut rng = Rng::seeded(4);
        let global: GaugeField = GaugeField::random(&ggeom, &mut rng);

        for rank in 0..grid.size() {
            let lgeom = Geometry::for_rank(global_dims, grid, rank, tiling).unwrap();
            let local = extract_gauge(&global, &lgeom);
            let origin = lgeom.origin();
            // spot-check a few local lexical coordinates
            for (x, y, z, t) in [(0, 0, 0, 0), (3, 1, 2, 1), (2, 3, 3, 0)] {
                let want = global.link_at(
                    Dir::Y,
                    origin[0] + x,
                    origin[1] + y,
                    origin[2] + z,
                    origin[3] + t,
                );
                let got = local.link_at(Dir::Y, x, y, z, t);
                assert!(got.dist(&want) < 1e-12, "rank {rank} site ({x},{y},{z},{t})");
            }
        }
    }
}
