//! Halo-exchange plans for the even-odd hopping (paper §3.5).
//!
//! The exchange sends *projected half-spinors* (12 f32/site), halving the
//! traffic vs full spinors, with the QWS/QXS division of labor:
//!
//! * **upward export** (to the +d neighbor): the receiver's backward hop
//!   `(1 + g_d) U_d^dag(x-d) psi(x-d)` needs `U^dag * proj+`, and the
//!   *sender* applies the 3x3 link multiplication (EO1 does the U-mult
//!   for data exported upward);
//! * **downward export** (to the -d neighbor): the receiver's forward hop
//!   `(1 - g_d) U_d(x) psi(x+d)` needs only `proj-`; the *receiver*
//!   multiplies its local link (EO2 does the U-mult for data imported
//!   from upward).
//!
//! The x-face site sets are irregular in the compacted layout: only the
//! rows whose row parity places a site on the face participate (Fig. 7:
//! "two of the sixteen elements need to be sent"). The site lists below
//! are exactly the index vectors the `compact`/`tbl` instructions consume
//! on A64FX.
//!
//! Buffer ordering contract: every rank enumerates face sites in the
//! canonical (t, z, y, ix) order, and for the x-direction the sender and
//! receiver rows pair up because `phi_in = 1 - phi_out` on matching rows.
//! All ranks share the same local dims, so sender position k lands at
//! receiver position k.

use crate::lattice::{EoLayout, EvenOdd, Geometry, Parity, SiteCoord};

/// Number of f32 per packed site: 2 spin x 3 color x (re, im).
pub const HALF_SPINOR_F32: usize = 12;

/// Sentinel for "site not on this face".
pub const NOT_ON_FACE: u32 = u32::MAX;

/// Flat canonical index of a compacted site (t, z, y, ix order).
#[inline]
pub fn flat_site(l: &EoLayout, s: SiteCoord) -> usize {
    let ny = l.nyt * l.tiling.vy();
    let nxh = l.nxt * l.tiling.vx();
    ((s.t * l.nz + s.z) * ny + s.y) * nxh + s.ix
}

/// Inverse of [`flat_site`].
#[inline]
pub fn site_from_flat(l: &EoLayout, flat: usize) -> SiteCoord {
    let ny = l.nyt * l.tiling.vy();
    let nxh = l.nxt * l.tiling.vx();
    let ix = flat % nxh;
    let r = flat / nxh;
    let y = r % ny;
    let r = r / ny;
    let z = r % l.nz;
    let t = r / l.nz;
    SiteCoord { t, z, y, ix }
}

/// Halo plans of one rank for one output parity.
#[derive(Clone, Debug)]
pub struct HaloPlans {
    pub p_out: Parity,
    /// which directions exchange halos (grid > 1 or forced self-comm)
    pub comm: [bool; 4],
    /// EO1 upward-export source sites (parity p_in, high face of d);
    /// packed as U^dag * proj+.
    pub up_export: [Vec<SiteCoord>; 4],
    /// EO1 downward-export source sites (parity p_in, low face of d);
    /// packed as proj- only.
    pub down_export: [Vec<SiteCoord>; 4],
    /// EO2: flat output-site index -> position in the buffer imported from
    /// the +d neighbor (output site on the high face; needs local U-mult).
    pub up_import_pos: [Vec<u32>; 4],
    /// EO2: flat output-site index -> position in the buffer imported from
    /// the -d neighbor (output site on the low face; pre-multiplied).
    pub down_import_pos: [Vec<u32>; 4],
    /// number of sites in each direction's face buffer
    pub face_count: [usize; 4],
    pub nsites: usize,
}

impl HaloPlans {
    pub fn new(geom: &Geometry, p_out: Parity, comm: [bool; 4]) -> HaloPlans {
        let l = EoLayout::new(geom);
        let d = geom.local;
        let p_in = p_out.flip();
        let (ny, nxh) = (d.y, d.xh());
        let nsites = d.half_volume();

        let mut plans = HaloPlans {
            p_out,
            comm,
            up_export: Default::default(),
            down_export: Default::default(),
            up_import_pos: std::array::from_fn(|_| Vec::new()),
            down_import_pos: std::array::from_fn(|_| Vec::new()),
            face_count: [0; 4],
            nsites,
        };

        for dir in 0..4 {
            if !comm[dir] {
                continue;
            }
            plans.up_import_pos[dir] = vec![NOT_ON_FACE; nsites];
            plans.down_import_pos[dir] = vec![NOT_ON_FACE; nsites];

            if dir == 0 {
                // ---- x faces: one site per qualifying row -------------
                let (mut cnt_up_exp, mut cnt_dn_exp) = (0u32, 0u32);
                for t in 0..d.t {
                    for z in 0..d.z {
                        for y in 0..ny {
                            let phi_in = EvenOdd::row_parity(y, z, t, p_in);
                            if phi_in == 1 {
                                // source x = 2*(XH-1)+1 = NX-1: high face
                                plans.up_export[0].push(SiteCoord {
                                    t,
                                    z,
                                    y,
                                    ix: nxh - 1,
                                });
                                // same row on the receive side: phi_out = 0,
                                // output site x = 0 imports from downward
                                let s = SiteCoord { t, z, y, ix: 0 };
                                plans.down_import_pos[0][flat_site(&l, s)] =
                                    cnt_up_exp;
                                cnt_up_exp += 1;
                            } else {
                                // source x = 0: low face
                                plans.down_export[0].push(SiteCoord {
                                    t,
                                    z,
                                    y,
                                    ix: 0,
                                });
                                // phi_out = 1: output site x = NX-1 imports
                                // from upward
                                let s = SiteCoord {
                                    t,
                                    z,
                                    y,
                                    ix: nxh - 1,
                                };
                                plans.up_import_pos[0][flat_site(&l, s)] =
                                    cnt_dn_exp;
                                cnt_dn_exp += 1;
                            }
                        }
                    }
                }
                assert_eq!(
                    cnt_up_exp, cnt_dn_exp,
                    "x faces must split rows evenly (even row count)"
                );
                plans.face_count[0] = cnt_up_exp as usize;
            } else {
                // ---- y/z/t faces: full 3D slabs -----------------------
                // Separate dense counters per face: a receiver's hi-face
                // site at (coords with the d-coordinate dropped) pairs
                // with the sender's lo-face site at the same dropped
                // coordinates; both sides enumerate in (t, z, y, ix)
                // order, so position = dense index in face order.
                for t in 0..d.t {
                    for z in 0..d.z {
                        for y in 0..ny {
                            let on_hi = match dir {
                                1 => y == d.y - 1,
                                2 => z == d.z - 1,
                                _ => t == d.t - 1,
                            };
                            let on_lo = match dir {
                                1 => y == 0,
                                2 => z == 0,
                                _ => t == 0,
                            };
                            if !(on_hi || on_lo) {
                                continue;
                            }
                            for ix in 0..nxh {
                                let s = SiteCoord { t, z, y, ix };
                                if on_hi {
                                    plans.up_export[dir].push(s);
                                    // hi-face output sites import from the
                                    // +d neighbor (its lo face, same dense
                                    // order)
                                    plans.up_import_pos[dir][flat_site(&l, s)] =
                                        (plans.up_export[dir].len() - 1) as u32;
                                }
                                if on_lo {
                                    plans.down_export[dir].push(s);
                                    // lo-face output sites import from the
                                    // -d neighbor (its hi face)
                                    plans.down_import_pos[dir][flat_site(&l, s)] =
                                        (plans.down_export[dir].len() - 1) as u32;
                                }
                            }
                        }
                    }
                }
                plans.face_count[dir] = plans.up_export[dir].len();
                assert_eq!(
                    plans.up_export[dir].len(),
                    plans.down_export[dir].len()
                );
            }
        }
        plans
    }

    /// f32 length of one face buffer in direction `dir`.
    pub fn buffer_len(&self, dir: usize) -> usize {
        self.face_count[dir] * HALF_SPINOR_F32
    }

    /// Real length of one *batched* face buffer in direction `dir`
    /// carrying `nact` active right-hand sides: the same face sites, with
    /// the RHS axis innermost on the wire (`[site][rhs][12]`), so one
    /// message per direction serves the whole batch and masked RHS cost
    /// zero bytes.
    pub fn buffer_len_multi(&self, dir: usize, nact: usize) -> usize {
        self.face_count[dir] * nact * HALF_SPINOR_F32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::{Geometry, LatticeDims, Tiling};

    fn geom() -> Geometry {
        Geometry::single_rank(
            LatticeDims::new(8, 4, 4, 6).unwrap(),
            Tiling::new(2, 2).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn flat_site_roundtrip() {
        let g = geom();
        let l = EoLayout::new(&g);
        for (i, s) in l.sites().enumerate() {
            assert_eq!(flat_site(&l, s), i, "canonical order is flat order");
            assert_eq!(site_from_flat(&l, i), s);
        }
    }

    #[test]
    fn face_counts() {
        let g = geom();
        let d = g.local;
        for p in Parity::BOTH {
            let plans = HaloPlans::new(&g, p, [true; 4]);
            // x face: half the rows
            assert_eq!(plans.face_count[0], d.y * d.z * d.t / 2);
            // y/z/t faces: full slabs of the compacted lattice
            assert_eq!(plans.face_count[1], d.xh() * d.z * d.t);
            assert_eq!(plans.face_count[2], d.xh() * d.y * d.t);
            assert_eq!(plans.face_count[3], d.xh() * d.y * d.z);
        }
    }

    #[test]
    fn fig7_two_of_sixteen() {
        // 4x4 tiling: a 4x4-site tile row block has 4 lane rows, of which
        // 2 have the face site -> 2 of 16 lanes per vector are sent.
        let g = Geometry::single_rank(
            LatticeDims::new(16, 16, 4, 4).unwrap(),
            Tiling::new(4, 4).unwrap(),
        )
        .unwrap();
        let plans = HaloPlans::new(&g, Parity::Odd, [true; 4]);
        // per x-edge tile: vy = 4 lane rows, half qualify -> 2 of the 16
        // lanes of each boundary vector are sent, as in Fig. 7
        let edge_tiles = (16 / 4) * 4 * 4; // (NY/VLENY) * NZ * NT
        assert_eq!(plans.face_count[0] / edge_tiles, 2);
    }

    #[test]
    fn import_positions_cover_buffer_exactly() {
        let g = geom();
        let plans = HaloPlans::new(&g, Parity::Even, [true; 4]);
        for dir in 0..4 {
            for pos_map in [&plans.up_import_pos[dir], &plans.down_import_pos[dir]] {
                let mut seen = vec![false; plans.face_count[dir]];
                for &p in pos_map.iter().filter(|&&p| p != NOT_ON_FACE) {
                    assert!(!seen[p as usize], "duplicate buffer position");
                    seen[p as usize] = true;
                }
                assert!(seen.iter().all(|&b| b), "buffer hole in dir {dir}");
            }
        }
    }

    #[test]
    fn export_sites_have_source_parity_face_coords() {
        let g = geom();
        let d = g.local;
        let p_out = Parity::Odd;
        let plans = HaloPlans::new(&g, p_out, [true; 4]);
        // x: upward-export sites must sit at lexical x = NX-1 for p_in
        for s in &plans.up_export[0] {
            let phi = EvenOdd::row_parity(s.y, s.z, s.t, p_out.flip());
            assert_eq!(EvenOdd::lexical_x(s.ix, phi), d.x - 1);
        }
        for s in &plans.down_export[0] {
            let phi = EvenOdd::row_parity(s.y, s.z, s.t, p_out.flip());
            assert_eq!(EvenOdd::lexical_x(s.ix, phi), 0);
        }
        // t: slabs
        assert!(plans.up_export[3].iter().all(|s| s.t == d.t - 1));
        assert!(plans.down_export[3].iter().all(|s| s.t == 0));
    }

    #[test]
    fn disabled_directions_empty() {
        let g = geom();
        let plans = HaloPlans::new(&g, Parity::Even, [false, false, true, false]);
        assert!(plans.up_export[0].is_empty());
        assert!(plans.up_import_pos[0].is_empty());
        assert_eq!(plans.face_count[2], g.local.xh() * g.local.y * g.local.t);
    }
}
