//! EO2: merge the received halo data into the output field (paper §3.6,
//! Fig. 7 bottom, Fig. 9 bottom).
//!
//! Faithful to the paper's structure: EO2 is a *single loop over all local
//! output sites*; each site checks every communicated direction for an
//! incoming contribution. "The number of boundaries concerning each site
//! depends on the place of the site on the local lattice", so uniformly
//! splitting the flat site range over threads is load-imbalanced — sites
//! owned by the last thread (the high-t slab in canonical order) all
//! import from the upward t-process and pay the 3x3 U-multiplication.
//! This is exactly the Fig. 9 imbalance; [`super::balance`] provides the
//! cost-weighted partition the paper proposes as future work.
//!
//! Delivery of buffer entries to lattice lanes through the precomputed
//! position maps is the software analog of the `tbl` delivery in Fig. 7.

use crate::algebra::{Real, Spinor, PROJ};
use crate::dslash::links::LinkSource;
use crate::field::FermionField;
use crate::lattice::{Dir, SiteCoord};

use super::halo::{site_from_flat, HaloPlans, HALF_SPINOR_F32, NOT_ON_FACE};
use super::pack::read_half;

/// Received buffers for one hopping application, indexed by direction.
/// The wire scalar follows the field precision.
#[derive(Clone, Debug)]
pub struct RecvBuffers<R: Real = f32> {
    /// from the +d neighbor (output sites on the high face; needs U-mult)
    pub from_up: [Vec<R>; 4],
    /// from the -d neighbor (pre-multiplied by the sender)
    pub from_down: [Vec<R>; 4],
}

impl<R: Real> Default for RecvBuffers<R> {
    fn default() -> Self {
        RecvBuffers {
            from_up: std::array::from_fn(|_| Vec::new()),
            from_down: std::array::from_fn(|_| Vec::new()),
        }
    }
}

/// EO2 cost of one site (used by the balancer and the profiler):
/// reconstruction ~24, the U-mult ~144, plus a small constant for the
/// per-site face scan that every site pays (interior included) — without
/// it the balancer would hand one thread almost all interior sites.
pub fn site_cost(plans: &HaloPlans, flat: usize) -> u64 {
    let mut cost = 3;
    for dir in 0..4 {
        if !plans.comm[dir] {
            continue;
        }
        if plans.up_import_pos[dir][flat] != NOT_ON_FACE {
            cost += 144 + 24; // U-mult + reconstruct
        }
        if plans.down_import_pos[dir][flat] != NOT_ON_FACE {
            cost += 24; // reconstruct only
        }
    }
    cost
}

/// Process the flat output-site range `[begin, end)`: add every incoming
/// halo contribution to `out`.
pub fn eo2_range<R: Real, U: LinkSource<R>>(
    out: &mut FermionField<R>,
    plans: &HaloPlans,
    bufs: &RecvBuffers<R>,
    u: &U,
    begin: usize,
    end: usize,
) {
    let l = out.layout;
    let ptr = crate::coordinator::team::SendPtr(out.data.as_mut_ptr());
    // SAFETY: single-threaded call, so the range is trivially disjoint
    // and `ptr` borrows the live `out` buffer of layout `l`.
    unsafe { eo2_range_raw(ptr, &l, plans, bufs, u, begin, end) }
}

/// Raw-pointer variant for the thread team: each thread processes a
/// disjoint flat-site range of the same output buffer.
///
/// # Safety
/// Ranges given to concurrent callers must be disjoint; `out` must point
/// at a live buffer laid out by `l`.
pub unsafe fn eo2_range_raw<R: Real, U: LinkSource<R>>(
    out: crate::coordinator::team::SendPtr<R>,
    l: &crate::lattice::EoLayout,
    plans: &HaloPlans,
    bufs: &RecvBuffers<R>,
    u: &U,
    begin: usize,
    end: usize,
) {
    for flat in begin..end {
        // fast path: most sites are interior
        let mut touched = false;
        for dir in 0..4 {
            if plans.comm[dir]
                && (plans.up_import_pos[dir][flat] != NOT_ON_FACE
                    || plans.down_import_pos[dir][flat] != NOT_ON_FACE)
            {
                touched = true;
                break;
            }
        }
        if !touched {
            continue;
        }
        let s: SiteCoord = site_from_flat(l, flat);
        let mut acc = Spinor::ZERO;
        for dir in 0..4 {
            if !plans.comm[dir] {
                continue;
            }
            // import from the +d neighbor: forward hop at the high face;
            // multiply the local link U_d(x) then reconstruct with (1 - g)
            let pos = plans.up_import_pos[dir][flat];
            if pos != NOT_ON_FACE {
                let off = pos as usize * HALF_SPINOR_F32;
                let h = read_half(&bufs.from_up[dir][off..off + HALF_SPINOR_F32]);
                let w = h.link_mul(&u.site_link(Dir::from_index(dir), plans.p_out, s));
                PROJ[dir][0].reconstruct_accum(&mut acc, &w);
            }
            // import from the -d neighbor: backward hop at the low face;
            // the sender already multiplied U^dag, just reconstruct (1 + g)
            let pos = plans.down_import_pos[dir][flat];
            if pos != NOT_ON_FACE {
                let off = pos as usize * HALF_SPINOR_F32;
                let w = read_half(&bufs.from_down[dir][off..off + HALF_SPINOR_F32]);
                PROJ[dir][1].reconstruct_accum(&mut acc, &w);
            }
        }
        // accumulate into the output storage (read-modify-write through
        // the raw pointer; sites in [begin, end) are storage-disjoint)
        let lc = l.site_to_lane(s);
        for spin in 0..4 {
            for color in 0..3 {
                let ro = l.spinor_vec(lc.tile, spin, color, 0) + lc.lane;
                let io = l.spinor_vec(lc.tile, spin, color, 1) + lc.lane;
                *out.0.add(ro) += R::from_f64(acc.s[spin][color].re);
                *out.0.add(io) += R::from_f64(acc.s[spin][color].im);
            }
        }
    }
}

/// [`eo2_range_raw`] with the M-hat xpay tail `out = a * out + b` fused
/// into the same pass: every site of the range gets the tail applied,
/// and sites with incoming halo contributions accumulate them *first* —
/// exactly the value and rounding order of `eo2_range_raw` followed by
/// a separate full-field `FermionField::xpay(a, b)` sweep, so the fused
/// distributed M-hat is bit-identical to the two-pass reference while
/// saving the xpay's 3 full-field memory streams as a separate pass.
///
/// # Safety
/// Same contract as [`eo2_range_raw`]; additionally `b` must point at a
/// live field of the same layout.
#[allow(clippy::too_many_arguments)]
pub unsafe fn eo2_tail_range_raw<R: Real, U: LinkSource<R>>(
    out: crate::coordinator::team::SendPtr<R>,
    l: &crate::lattice::EoLayout,
    plans: &HaloPlans,
    bufs: &RecvBuffers<R>,
    u: &U,
    begin: usize,
    end: usize,
    a: R,
    b: *const R,
) {
    for flat in begin..end {
        let mut touched = false;
        for dir in 0..4 {
            if plans.comm[dir]
                && (plans.up_import_pos[dir][flat] != NOT_ON_FACE
                    || plans.down_import_pos[dir][flat] != NOT_ON_FACE)
            {
                touched = true;
                break;
            }
        }
        let s: SiteCoord = site_from_flat(l, flat);
        let mut acc = Spinor::ZERO;
        if touched {
            for dir in 0..4 {
                if !plans.comm[dir] {
                    continue;
                }
                let pos = plans.up_import_pos[dir][flat];
                if pos != NOT_ON_FACE {
                    let off = pos as usize * HALF_SPINOR_F32;
                    let h = read_half(&bufs.from_up[dir][off..off + HALF_SPINOR_F32]);
                    let w = h.link_mul(&u.site_link(Dir::from_index(dir), plans.p_out, s));
                    PROJ[dir][0].reconstruct_accum(&mut acc, &w);
                }
                let pos = plans.down_import_pos[dir][flat];
                if pos != NOT_ON_FACE {
                    let off = pos as usize * HALF_SPINOR_F32;
                    let w = read_half(&bufs.from_down[dir][off..off + HALF_SPINOR_F32]);
                    PROJ[dir][1].reconstruct_accum(&mut acc, &w);
                }
            }
        }
        let lc = l.site_to_lane(s);
        for spin in 0..4 {
            for color in 0..3 {
                let ro = l.spinor_vec(lc.tile, spin, color, 0) + lc.lane;
                let io = l.spinor_vec(lc.tile, spin, color, 1) + lc.lane;
                // accumulate-then-xpay in the reference order: the halo
                // add rounds into R first, then the tail rounds once
                let mut re = *out.0.add(ro);
                let mut im = *out.0.add(io);
                if touched {
                    re += R::from_f64(acc.s[spin][color].re);
                    im += R::from_f64(acc.s[spin][color].im);
                }
                *out.0.add(ro) = a * re + *b.add(ro);
                *out.0.add(io) = a * im + *b.add(io);
            }
        }
    }
}

/// Per-RHS fused tail of the batched EO2 merge pass. `b` points at a
/// block field of the output's layout (sub-tile indexed like the output).
#[derive(Clone, Copy)]
pub enum MultiEo2Tail<R: Real> {
    /// halo merge only (interior sites untouched)
    None,
    /// out_r = a * out_r + b_r on every site of every active RHS
    Xpay {
        a: R,
        b: crate::coordinator::team::SendPtr<R>,
    },
    /// out_r = gamma5 * (a * out_r + b_r)
    Gamma5Xpay {
        a: R,
        b: crate::coordinator::team::SendPtr<R>,
    },
}

/// Batched EO2: merge the received multi-RHS halo buffers (RHS-innermost
/// on the wire, active RHS only) into a block-field output, optionally
/// fusing the per-RHS M-hat xpay / gamma5-xpay tail into the same pass.
///
/// Per-(direction, site) the local link is fetched **once** and consumed
/// by every active RHS — the EO2 analog of the bulk kernel's gauge
/// amortization — while the per-RHS accumulate/reconstruct/tail
/// arithmetic is exactly [`eo2_range_raw`] / [`eo2_tail_range_raw`]'s,
/// so each active RHS bit-matches the single-RHS merge of its demuxed
/// field. Masked RHS are neither read nor written (frozen), including by
/// the tail.
///
/// # Safety
/// Same contract as [`eo2_range_raw`] with block-field lengths; ranges
/// given to concurrent callers must be disjoint; a tail's `b` must point
/// at a live block field of the same layout.
#[allow(clippy::too_many_arguments)]
pub unsafe fn eo2_multi_range_raw<R: Real, U: LinkSource<R>>(
    out: crate::coordinator::team::SendPtr<R>,
    l: &crate::lattice::EoLayout,
    plans: &HaloPlans,
    bufs: &RecvBuffers<R>,
    u: &U,
    nrhs: usize,
    active: &[bool],
    begin: usize,
    end: usize,
    tail: MultiEo2Tail<R>,
) {
    let nact = active.iter().filter(|&&a| a).count();
    let mut accs = vec![Spinor::ZERO; nrhs];
    for flat in begin..end {
        let mut touched = false;
        for dir in 0..4 {
            if plans.comm[dir]
                && (plans.up_import_pos[dir][flat] != NOT_ON_FACE
                    || plans.down_import_pos[dir][flat] != NOT_ON_FACE)
            {
                touched = true;
                break;
            }
        }
        if !touched && matches!(tail, MultiEo2Tail::None) {
            continue;
        }
        let s: SiteCoord = site_from_flat(l, flat);
        if touched {
            for (r, &on) in active.iter().enumerate() {
                if on {
                    accs[r] = Spinor::ZERO;
                }
            }
            for dir in 0..4 {
                if !plans.comm[dir] {
                    continue;
                }
                // +d import: fetch the local link once, feed all RHS
                let pos = plans.up_import_pos[dir][flat];
                if pos != NOT_ON_FACE {
                    let link = u.site_link(Dir::from_index(dir), plans.p_out, s);
                    let base = pos as usize * nact * HALF_SPINOR_F32;
                    let mut slot = 0;
                    for (r, &on) in active.iter().enumerate() {
                        if !on {
                            continue;
                        }
                        let off = base + slot * HALF_SPINOR_F32;
                        let h =
                            read_half(&bufs.from_up[dir][off..off + HALF_SPINOR_F32]);
                        let w = h.link_mul(&link);
                        PROJ[dir][0].reconstruct_accum(&mut accs[r], &w);
                        slot += 1;
                    }
                }
                // -d import: pre-multiplied by the sender
                let pos = plans.down_import_pos[dir][flat];
                if pos != NOT_ON_FACE {
                    let base = pos as usize * nact * HALF_SPINOR_F32;
                    let mut slot = 0;
                    for (r, &on) in active.iter().enumerate() {
                        if !on {
                            continue;
                        }
                        let off = base + slot * HALF_SPINOR_F32;
                        let w =
                            read_half(&bufs.from_down[dir][off..off + HALF_SPINOR_F32]);
                        PROJ[dir][1].reconstruct_accum(&mut accs[r], &w);
                        slot += 1;
                    }
                }
            }
        }
        let lc = l.site_to_lane(s);
        for (r, &on) in active.iter().enumerate() {
            if !on {
                continue;
            }
            let sub = lc.tile * nrhs + r;
            for spin in 0..4 {
                for color in 0..3 {
                    let ro = l.spinor_vec(sub, spin, color, 0) + lc.lane;
                    let io = l.spinor_vec(sub, spin, color, 1) + lc.lane;
                    // accumulate-then-tail in the single-RHS reference
                    // order: halo add rounds into R first, the tail
                    // rounds once
                    let mut re = *out.0.add(ro);
                    let mut im = *out.0.add(io);
                    if touched {
                        re += R::from_f64(accs[r].s[spin][color].re);
                        im += R::from_f64(accs[r].s[spin][color].im);
                    }
                    match tail {
                        MultiEo2Tail::None => {
                            if touched {
                                *out.0.add(ro) = re;
                                *out.0.add(io) = im;
                            }
                        }
                        MultiEo2Tail::Xpay { a, b } => {
                            *out.0.add(ro) = a * re + *b.0.add(ro);
                            *out.0.add(io) = a * im + *b.0.add(io);
                        }
                        MultiEo2Tail::Gamma5Xpay { a, b } => {
                            let vr = a * re + *b.0.add(ro);
                            let vi = a * im + *b.0.add(io);
                            // gamma5 negates the lower two spins, like
                            // the kernel's Gamma5Xpay store tail
                            if spin >= 2 {
                                *out.0.add(ro) = -vr;
                                *out.0.add(io) = -vi;
                            } else {
                                *out.0.add(ro) = vr;
                                *out.0.add(io) = vi;
                            }
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::{Geometry, LatticeDims, Parity, Tiling};

    #[test]
    fn site_cost_zero_in_interior_and_positive_on_faces() {
        let geom = Geometry::single_rank(
            LatticeDims::new(8, 4, 4, 4).unwrap(),
            Tiling::new(2, 2).unwrap(),
        )
        .unwrap();
        let plans = HaloPlans::new(&geom, Parity::Odd, [true; 4]);
        let l = crate::lattice::EoLayout::new(&geom);
        let mut interior = 0;
        let mut corner_cost = 0;
        for flat in 0..plans.nsites {
            let c = site_cost(&plans, flat);
            let s = site_from_flat(&l, flat);
            let on_t_face = s.t == 0 || s.t == 3;
            if !on_t_face && s.z != 0 && s.z != 3 && s.y != 0 && s.y != 3 {
                // may still be on the x face; just track interior count
            }
            if c == 3 {
                // base scan cost only: no face contributions
                interior += 1;
            }
            corner_cost = corner_cost.max(c);
        }
        assert!(interior > 0, "some sites must be pure bulk");
        assert!(site_cost(&plans, 0) > 3, "flat 0 is the origin corner");
        // a site on several faces pays several contributions
        assert!(corner_cost >= 2 * (144 + 24) || corner_cost >= 144 + 24 + 24);
    }
}
