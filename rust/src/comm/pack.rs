//! EO1: pack the send buffers (paper §3.5-3.6, Fig. 7 top, Fig. 9 top).
//!
//! Each direction's boundary loop runs independently and is *averagely*
//! parallelized over the threads (ranges of the face-site lists), which is
//! why EO1's thread load is well balanced in Fig. 9. Upward exports carry
//! the `U^dag * proj+` product (the sender does the 3x3 multiply);
//! downward exports carry only `proj-`.
//!
//! Buffers are generic over the [`Real`] wire scalar: the halo of an f64
//! field travels as f64 (12 reals per site either way), so the
//! distributed operator is exact at every precision.
//!
//! The per-site write of 12 consecutive reals from lanes selected by the
//! site list is the software analog of the SVE `compact` instruction.

use crate::algebra::{Real, PROJ};
use crate::dslash::links::LinkSource;
use crate::field::{FermionField, MultiFermionField};
use crate::lattice::{Dir, SiteCoord};

use super::halo::{HaloPlans, HALF_SPINOR_F32};

/// Pack a range of the upward-export list of direction `dir` into `buf`.
///
/// Content per site: `U_dir^dag(x) * proj+_dir(psi(x))`, 12 reals.
pub fn pack_up_range<R: Real, U: LinkSource<R>>(
    buf: &mut [R],
    plans: &HaloPlans,
    dir: usize,
    u: &U,
    psi: &FermionField<R>,
    begin: usize,
    end: usize,
) {
    let p_in = plans.p_out.flip();
    let entry = &PROJ[dir][1];
    for i in begin..end {
        let s: SiteCoord = plans.up_export[dir][i];
        let h = entry.project(&psi.site(s));
        let w = h.link_adj_mul(&u.site_link(Dir::from_index(dir), p_in, s));
        write_half(&mut buf[i * HALF_SPINOR_F32..(i + 1) * HALF_SPINOR_F32], &w);
    }
}

/// Pack a range of the downward-export list of direction `dir` into `buf`.
///
/// Content per site: `proj-_dir(psi(x))`, 12 reals (no U-mult; the
/// receiver multiplies its local link).
pub fn pack_down_range<R: Real>(
    buf: &mut [R],
    plans: &HaloPlans,
    dir: usize,
    psi: &FermionField<R>,
    begin: usize,
    end: usize,
) {
    let entry = &PROJ[dir][0];
    for i in begin..end {
        let s: SiteCoord = plans.down_export[dir][i];
        let h = entry.project(&psi.site(s));
        write_half(&mut buf[i * HALF_SPINOR_F32..(i + 1) * HALF_SPINOR_F32], &h);
    }
}

#[inline]
fn write_half<R: Real>(dst: &mut [R], h: &crate::algebra::HalfSpinor) {
    let mut k = 0;
    for s in 0..2 {
        for c in 0..3 {
            dst[k] = R::from_f64(h.h[s][c].re);
            dst[k + 1] = R::from_f64(h.h[s][c].im);
            k += 2;
        }
    }
}

/// Alias used by the driver (reals per packed site, any precision).
pub const HALF_F32: usize = HALF_SPINOR_F32;

/// Like [`pack_up_range`] but `buf` starts at site `begin` (relative
/// addressing, for per-thread buffer sub-slices).
pub fn pack_up_range_rel<R: Real, U: LinkSource<R>>(
    buf: &mut [R],
    plans: &HaloPlans,
    dir: usize,
    u: &U,
    psi: &FermionField<R>,
    begin: usize,
    end: usize,
) {
    let p_in = plans.p_out.flip();
    let entry = &PROJ[dir][1];
    for i in begin..end {
        let s: SiteCoord = plans.up_export[dir][i];
        let h = entry.project(&psi.site(s));
        let w = h.link_adj_mul(&u.site_link(Dir::from_index(dir), p_in, s));
        let k = (i - begin) * HALF_SPINOR_F32;
        write_half(&mut buf[k..k + HALF_SPINOR_F32], &w);
    }
}

/// Like [`pack_down_range`] but with relative buffer addressing.
pub fn pack_down_range_rel<R: Real>(
    buf: &mut [R],
    plans: &HaloPlans,
    dir: usize,
    psi: &FermionField<R>,
    begin: usize,
    end: usize,
) {
    let entry = &PROJ[dir][0];
    for i in begin..end {
        let s: SiteCoord = plans.down_export[dir][i];
        let h = entry.project(&psi.site(s));
        let k = (i - begin) * HALF_SPINOR_F32;
        write_half(&mut buf[k..k + HALF_SPINOR_F32], &h);
    }
}

/// Batched [`pack_up_range_rel`]: pack the upward-export sites
/// `[begin, end)` of direction `dir` for every *active* RHS of a block
/// field, RHS-innermost on the wire (`[site][active rhs][12]`). The
/// site's link is fetched once and applied to all active RHS — the halo
/// pack amortizes the gauge access exactly like the bulk kernel — and
/// the per-RHS arithmetic (project, `U^dag` multiply, rounding) is the
/// single-RHS pack's, so each active RHS's payload bit-matches what
/// [`pack_up_range_rel`] would produce for its demuxed field.
#[allow(clippy::too_many_arguments)]
pub fn pack_up_multi_rel<R: Real, U: LinkSource<R>>(
    buf: &mut [R],
    plans: &HaloPlans,
    dir: usize,
    u: &U,
    psi: &MultiFermionField<R>,
    active: &[bool],
    begin: usize,
    end: usize,
) {
    let p_in = plans.p_out.flip();
    let entry = &PROJ[dir][1];
    let nact = active.iter().filter(|&&a| a).count();
    for i in begin..end {
        let s: SiteCoord = plans.up_export[dir][i];
        let link = u.site_link(Dir::from_index(dir), p_in, s);
        let mut k = (i - begin) * nact * HALF_SPINOR_F32;
        for (r, &on) in active.iter().enumerate() {
            if !on {
                continue;
            }
            let h = entry.project(&psi.site_rhs(s, r));
            let w = h.link_adj_mul(&link);
            write_half(&mut buf[k..k + HALF_SPINOR_F32], &w);
            k += HALF_SPINOR_F32;
        }
    }
}

/// Batched [`pack_down_range_rel`]: `proj-` only, per active RHS,
/// RHS-innermost on the wire.
pub fn pack_down_multi_rel<R: Real>(
    buf: &mut [R],
    plans: &HaloPlans,
    dir: usize,
    psi: &MultiFermionField<R>,
    active: &[bool],
    begin: usize,
    end: usize,
) {
    let entry = &PROJ[dir][0];
    let nact = active.iter().filter(|&&a| a).count();
    for i in begin..end {
        let s: SiteCoord = plans.down_export[dir][i];
        let mut k = (i - begin) * nact * HALF_SPINOR_F32;
        for (r, &on) in active.iter().enumerate() {
            if !on {
                continue;
            }
            let h = entry.project(&psi.site_rhs(s, r));
            write_half(&mut buf[k..k + HALF_SPINOR_F32], &h);
            k += HALF_SPINOR_F32;
        }
    }
}

/// Read one packed half-spinor back (EO2 side).
#[inline]
pub fn read_half<R: Real>(src: &[R]) -> crate::algebra::HalfSpinor {
    let mut h = crate::algebra::HalfSpinor::default();
    let mut k = 0;
    for s in 0..2 {
        for c in 0..3 {
            h.h[s][c] =
                crate::algebra::Complex::new(src[k].to_f64(), src[k + 1].to_f64());
            k += 2;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algebra::{Complex, HalfSpinor};
    use crate::field::GaugeField;
    use crate::lattice::{Geometry, LatticeDims, Parity, Tiling};
    use crate::util::rng::Rng;

    #[test]
    fn half_spinor_roundtrip() {
        let mut rng = Rng::seeded(4);
        let mut h = HalfSpinor::default();
        for s in 0..2 {
            for c in 0..3 {
                h.h[s][c] = Complex::new(rng.gaussian(), rng.gaussian());
            }
        }
        let mut buf = vec![0.0f32; HALF_SPINOR_F32];
        write_half(&mut buf, &h);
        let back = read_half(&buf);
        for s in 0..2 {
            for c in 0..3 {
                assert!((back.h[s][c] - h.h[s][c]).abs() < 1e-6);
            }
        }
        // f64 wire: lossless
        let mut buf = vec![0.0f64; HALF_SPINOR_F32];
        write_half(&mut buf, &h);
        let back = read_half(&buf);
        for s in 0..2 {
            for c in 0..3 {
                assert_eq!(back.h[s][c], h.h[s][c]);
            }
        }
    }

    #[test]
    fn batched_pack_bit_matches_single_rhs_and_drops_masked() {
        let geom = Geometry::single_rank(
            LatticeDims::new(8, 4, 4, 4).unwrap(),
            Tiling::new(2, 2).unwrap(),
        )
        .unwrap();
        let mut rng = Rng::seeded(6);
        let u: GaugeField = GaugeField::random(&geom, &mut rng);
        let fields: Vec<FermionField<f32>> = (0..3)
            .map(|_| FermionField::gaussian(&geom, &mut rng))
            .collect();
        let m = crate::field::MultiFermionField::from_rhs(&fields);
        let plans = HaloPlans::new(&geom, Parity::Even, [true; 4]);
        let active = [true, false, true];
        let nact = 2;
        for dir in 0..4 {
            let n = plans.face_count[dir];
            let mut multi = vec![0.0f32; plans.buffer_len_multi(dir, nact)];
            pack_up_multi_rel(&mut multi, &plans, dir, &u, &m, &active, 0, n);
            // per active RHS the payload is byte-for-byte the single pack's
            for (slot, r) in [(0usize, 0usize), (1, 2)] {
                let mut single = vec![0.0f32; plans.buffer_len(dir)];
                pack_up_range(&mut single, &plans, dir, &u, &fields[r], 0, n);
                for site in 0..n {
                    let mo = (site * nact + slot) * HALF_SPINOR_F32;
                    let so = site * HALF_SPINOR_F32;
                    assert_eq!(
                        &multi[mo..mo + HALF_SPINOR_F32],
                        &single[so..so + HALF_SPINOR_F32],
                        "dir {dir} rhs {r} site {site}"
                    );
                }
            }
            // masked RHS cost zero bytes: the buffer is exactly nact wide
            assert_eq!(multi.len(), n * nact * HALF_SPINOR_F32);
            // down-exports too
            let mut multi = vec![0.0f32; plans.buffer_len_multi(dir, nact)];
            pack_down_multi_rel(&mut multi, &plans, dir, &m, &active, 0, n);
            let mut single = vec![0.0f32; plans.buffer_len(dir)];
            pack_down_range(&mut single, &plans, dir, &fields[2], 0, n);
            for site in 0..n {
                let mo = (site * nact + 1) * HALF_SPINOR_F32;
                let so = site * HALF_SPINOR_F32;
                assert_eq!(
                    &multi[mo..mo + HALF_SPINOR_F32],
                    &single[so..so + HALF_SPINOR_F32]
                );
            }
        }
    }

    #[test]
    fn pack_ranges_compose() {
        // packing [0, n) in one go == packing [0, k) + [k, n)
        let geom = Geometry::single_rank(
            LatticeDims::new(8, 4, 4, 4).unwrap(),
            Tiling::new(2, 2).unwrap(),
        )
        .unwrap();
        let mut rng = Rng::seeded(5);
        let u: GaugeField = GaugeField::random(&geom, &mut rng);
        let psi: FermionField = FermionField::gaussian(&geom, &mut rng);
        let plans = HaloPlans::new(&geom, Parity::Odd, [true; 4]);
        for dir in 0..4 {
            let n = plans.face_count[dir];
            let mut whole = vec![0.0f32; plans.buffer_len(dir)];
            pack_up_range(&mut whole, &plans, dir, &u, &psi, 0, n);
            let mut split = vec![0.0f32; plans.buffer_len(dir)];
            pack_up_range(&mut split, &plans, dir, &u, &psi, 0, n / 3);
            pack_up_range(&mut split, &plans, dir, &u, &psi, n / 3, n);
            assert_eq!(whole, split);
            assert!(whole.iter().any(|&v| v != 0.0));
        }
    }
}
