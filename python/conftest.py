import sys, pathlib
sys.path.insert(0, str(pathlib.Path(__file__).parent))
import jax
jax.config.update("jax_enable_x64", True)
