"""Tiny binary tensor format shared with the Rust side (rust/src/field/io.rs).

Layout (little-endian):
  magic   8 bytes  b"LQCD0001"
  dtype   u32      0 = f32, 1 = f64
  ndim    u32
  dims    u32 * ndim   (row-major / C order)
  data    dtype * prod(dims)

Used for golden test data (python writes, rust reads) and for field
checkpoints in the examples.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"LQCD0001"
_DTYPES = {0: np.float32, 1: np.float64}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.float64): 1}


def write_tensor(path, arr: np.ndarray) -> None:
    arr = np.ascontiguousarray(arr)
    code = _CODES[arr.dtype]
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", code, arr.ndim))
        f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
        f.write(arr.tobytes())


def read_tensor(path) -> np.ndarray:
    with open(path, "rb") as f:
        magic = f.read(8)
        if magic != MAGIC:
            raise ValueError(f"{path}: bad magic {magic!r}")
        code, ndim = struct.unpack("<II", f.read(8))
        dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
        dtype = _DTYPES[code]
        data = np.frombuffer(f.read(), dtype=dtype)
        return data.reshape(dims)


def complex_to_interleaved(arr: np.ndarray, dtype=np.float32) -> np.ndarray:
    """complex array -> trailing-[2] (re, im) float array."""
    return np.stack([arr.real, arr.imag], axis=-1).astype(dtype)


def interleaved_to_complex(arr: np.ndarray) -> np.ndarray:
    return arr[..., 0] + 1j * arr[..., 1]
