"""L2: the even-odd preconditioned Wilson operator and solver graphs.

Build-time only. These jax functions call the L1 Pallas kernel
(``kernels.wilson.hopping_eo``) and are lowered once by ``aot.py`` to HLO
text that the Rust runtime loads and executes; Python never runs on the
request path.

Interchange convention with Rust: every complex field crosses the boundary
as a single float32 array with a trailing ``[2]`` (re, im) axis —

  gauge (even-odd):  (4, 2, T, Z, Y, XH, 3, 3, 2)
  spinor (one parity): (T, Z, Y, XH, 4, 3, 2)

Operators (paper Eqs. 3-5), with D = 1 - kappa H in block form:

  M-hat psi_e = psi_e - kappa^2 H_eo H_oe psi_e        (Eq. 4 LHS)
  M-hat^dag    = g5 M-hat g5                           (gamma5-hermiticity)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels import wilson


def split(field):
    """Trailing-[2] interleaved array -> (re, im) pair."""
    return field[..., 0], field[..., 1]


def join(re, im):
    """(re, im) pair -> trailing-[2] interleaved array."""
    return jnp.stack([re, im], axis=-1)


def hopping(u, psi, p_out: int):
    """H_{p_out <- 1-p_out} on interleaved fields (wraps the L1 kernel)."""
    ur, ui = split(u)
    pr, pi = split(psi)
    hr, hi = wilson.hopping_eo(ur, ui, pr, pi, p_out)
    return join(hr, hi)


def gamma5(psi):
    """g5 psi in the chiral basis: flip the sign of spin components 2, 3."""
    sign = jnp.array([1.0, 1.0, -1.0, -1.0], dtype=psi.dtype)
    return psi * sign[:, None, None]


def meo(u, psi_e, kappa):
    """Even-odd preconditioned operator M-hat psi_e (Eq. 4 LHS)."""
    h_o = hopping(u, psi_e, p_out=1)
    h_e = hopping(u, h_o, p_out=0)
    return psi_e - (kappa * kappa) * h_e


def meo_dag(u, psi_e, kappa):
    """M-hat^dagger psi_e = g5 M-hat g5 psi_e (gamma5-hermiticity)."""
    return gamma5(meo(u, gamma5(psi_e), kappa))


def mdagm(u, psi_e, kappa):
    """Normal operator M-hat^dag M-hat (hermitian positive definite)."""
    return meo_dag(u, meo(u, psi_e, kappa), kappa)


def _dot_re(a, b):
    """Re <a, b> for interleaved complex fields (= plain f32 dot)."""
    return jnp.sum(a.astype(jnp.float64) * b.astype(jnp.float64)).astype(
        jnp.float32
    )


def cg_solve(u, b, kappa, tol: float, maxiter: int):
    """Whole-solver graph: CG on M-hat^dag M-hat x = M-hat^dag b.

    This is the "solver in XLA" variant; the Rust coordinator also drives
    its own CG calling the ``meo``/``mdagm`` artifacts per iteration.
    Returns (x, iterations, final |r|^2 / |b'|^2).
    """
    bp = meo_dag(u, b, kappa)
    bnorm = _dot_re(bp, bp)
    limit = tol * tol * bnorm

    def body(state):
        x, r, p, rr, k = state
        ap = mdagm(u, p, kappa)
        alpha = rr / _dot_re(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        rr_new = _dot_re(r, r)
        p = r + (rr_new / rr) * p
        return x, r, p, rr_new, k + 1

    def cond(state):
        _, _, _, rr, k = state
        return jnp.logical_and(rr > limit, k < maxiter)

    x0 = jnp.zeros_like(b)
    state = (x0, bp, bp, bnorm, jnp.int32(0))
    x, r, _, rr, k = jax.lax.while_loop(cond, body, state)
    return x, k, rr / bnorm


def dslash_eo_full(u, psi_e, psi_o, kappa):
    """Full Wilson matrix on an even/odd pair: (D psi)_e, (D psi)_o."""
    out_e = psi_e - kappa * hopping(u, psi_o, p_out=0)
    out_o = psi_o - kappa * hopping(u, psi_e, p_out=1)
    return out_e, out_o


def reconstruct_odd(u, b_o, x_e, kappa):
    """Eq. 5: xi_o = eta_o + kappa H_oe xi_e (D_oo = 1 for Wilson)."""
    return b_o + kappa * hopping(u, x_e, p_out=1)


def plaquette(u_full):
    """Average plaquette from the *lexical* gauge field.

    u_full: (4, T, Z, Y, X, 3, 3, 2) float32 interleaved.
    Returns a float32 scalar: <Re tr P> / 3 averaged over the 6 planes.
    """
    ur, ui = split(u_full)
    u = ur + 1j * ui
    total = jnp.float32(0.0)
    # Axis moved by direction mu in (4, T, Z, Y, X, 3, 3): x->4, y->3, z->2, t->1
    ax = {0: 4, 1: 3, 2: 2, 3: 1}
    for mu in range(4):
        for nu in range(mu + 1, 4):
            u_mu, u_nu = u[mu], u[nu]
            u_nu_xmu = jnp.roll(u_nu, -1, axis=ax[mu] - 1)
            u_mu_xnu = jnp.roll(u_mu, -1, axis=ax[nu] - 1)
            p = jnp.einsum(
                "...ab,...bc,...dc,...ed->...ae",
                u_mu,
                u_nu_xmu,
                jnp.conj(u_mu_xnu),
                jnp.conj(u_nu),
            )
            total = total + jnp.mean(
                jnp.real(jnp.trace(p, axis1=-2, axis2=-1))
            ).astype(jnp.float32)
    return total / jnp.float32(6.0 * 3.0)


def make_entry_points(dims, tol: float = 1e-10, maxiter: int = 1000):
    """The functions lowered to AOT artifacts, keyed by artifact name.

    ``dims`` is a layouts.LatticeDims; shapes are baked per artifact (XLA
    is shape-specialized). ``kappa`` stays a runtime scalar input.
    """
    t, z, y, xh = dims.t, dims.z, dims.y, dims.xh
    f32 = jnp.float32
    u_spec = jax.ShapeDtypeStruct((4, 2, t, z, y, xh, 3, 3, 2), f32)
    psi_spec = jax.ShapeDtypeStruct((t, z, y, xh, 4, 3, 2), f32)
    ufull_spec = jax.ShapeDtypeStruct((4, t, z, y, 2 * xh, 3, 3, 2), f32)
    k_spec = jax.ShapeDtypeStruct((), f32)

    return {
        "hopping_oe": (lambda u, p: hopping(u, p, 1), (u_spec, psi_spec)),
        "hopping_eo": (lambda u, p: hopping(u, p, 0), (u_spec, psi_spec)),
        "meo": (meo, (u_spec, psi_spec, k_spec)),
        "mdagm": (mdagm, (u_spec, psi_spec, k_spec)),
        "cg_solve": (
            functools.partial(cg_solve, tol=tol, maxiter=maxiter),
            (u_spec, psi_spec, k_spec),
        ),
        "reconstruct_odd": (
            reconstruct_odd,
            (u_spec, psi_spec, psi_spec, k_spec),
        ),
        "plaquette": (plaquette, (ufull_spec,)),
    }
