"""Pallas kernel for the even-odd Wilson hopping term (the paper's kernel).

This is the L1 hot-spot: ``H_{p_out <- p_in}`` applied to an x-*compacted*
even/odd spinor field, i.e. the ``D_eo`` / ``D_oe`` blocks of Eq. (3).

Faithful to the paper's implementation strategy (Sections 3.2-3.4):

* **Separate real/imaginary arrays** -- A64FX SVE has poor in-vector complex
  support, so QWS/QXS keep Re and Im in separate SIMD vectors; we keep them
  in separate arrays (``ur``/``ui``, ``pr``/``pi``).
* **Spin projection tables** -- (1 -+ gamma_mu) is applied as a 4->2 spinor
  projection with +-1/+-i coefficients and reconstructed after the SU(3)
  multiply (Fig. 2), never as a dense 4x4 matrix multiply.
* **Parity-select x-shift** (Fig. 5) -- on the compacted arrays, the +-x
  neighbor of a site at compact index ``ix`` lives at ``ix`` or ``ix +- 1``
  depending on the row parity ``phi = (y+z+t+p) mod 2``; the kernel uses a
  parity mask + lane roll, the TPU analog of the SVE ``sel`` + ``tbl`` pair.
  The y-shift is a plain roll (the ``ext`` analog, Fig. 6).

The kernel is lowered with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); correctness is pinned against ``ref.py`` by pytest.

Hardware adaptation (DESIGN.md section 3): the SVE 16-lane vector maps to the
trailing lane axes of the arrays; XLA owns the physical packing. The SU(3)
products are 3x3 complex GEMVs -- VPU work, not MXU work.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Axes of the compacted canonical (T, Z, Y, XH, ...) arrays.
AX_T, AX_Z, AX_Y, AX_XH = 0, 1, 2, 3

# Complex units used by the projection tables: (re, im).
ONE = (1.0, 0.0)
MONE = (-1.0, 0.0)
I_ = (0.0, 1.0)
MI = (0.0, -1.0)

# Spin projection / reconstruction tables for (1 - g_mu) [sign=0, forward hop]
# and (1 + g_mu) [sign=1, backward hop] in the DeGrand-Rossi basis.
#
# Entry: (j1, c1, j2, c2, k1, d1, k2, d2) meaning
#   h1 = psi_0 + c1 * psi_{j1}
#   h2 = psi_1 + c2 * psi_{j2}
#   r2 = d1 * h_{k1},  r3 = d2 * h_{k2}           (rows 0,1 of result = h1,h2)
#
# These are *derived* from the explicit gamma matrices in ref.py by
# python/tests/test_kernel.py::test_projection_tables -- do not edit by hand.
PROJ = {
    # mu = 0 (x)
    (0, 0): (3, MI, 2, MI, 1, I_, 0, I_),
    (0, 1): (3, I_, 2, I_, 1, MI, 0, MI),
    # mu = 1 (y)
    (1, 0): (3, ONE, 2, MONE, 1, MONE, 0, ONE),
    (1, 1): (3, MONE, 2, ONE, 1, ONE, 0, MONE),
    # mu = 2 (z)
    (2, 0): (2, MI, 3, I_, 0, I_, 1, MI),
    (2, 1): (2, I_, 3, MI, 0, MI, 1, I_),
    # mu = 3 (t)
    (3, 0): (2, MONE, 3, MONE, 0, MONE, 1, MONE),
    (3, 1): (2, ONE, 3, ONE, 0, ONE, 1, ONE),
}


def _cmul_const(v, c):
    """(re, im) * complex constant c, with exact special cases.

    Only +-1 and +-i ever appear in the tables; special-casing keeps the
    lowered HLO free of multiply-by-zero chains.
    """
    vr, vi = v
    if c == ONE:
        return vr, vi
    if c == MONE:
        return -vr, -vi
    if c == I_:
        return -vi, vr
    if c == MI:
        return vi, -vr
    cr, ci = c
    return cr * vr - ci * vi, cr * vi + ci * vr


def _cadd(a, b):
    return a[0] + b[0], a[1] + b[1]


def row_parity_mask(shape_eo: Sequence[int], parity: int, extra_dims: int):
    """phi(y,z,t;p) = (y+z+t+p) mod 2 as a bool mask, broadcastable.

    Returns shape (T, Z, Y, 1, [1]*extra_dims); True where phi == 1.
    Built from iota so it stays traceable inside the Pallas kernel.
    """
    t_, z_, y_, _ = shape_eo
    shape = (t_, z_, y_)
    it = jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    iz = jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    iy = jax.lax.broadcasted_iota(jnp.int32, shape, 2)
    phi = (it + iz + iy + parity) % 2
    return (phi == 1).reshape(shape + (1,) * (1 + extra_dims))


def shift_to_neighbor(v, mu: int, sign: int, p_out: int, extra_dims: int):
    """Return field(x + sign*mu_hat) as a field over parity-``p_out`` sites.

    ``v`` is an (re, im) pair of compacted arrays of parity 1 - p_out with
    shape (T, Z, Y, XH, ...extra...). Periodic boundaries via roll; the
    x-direction uses the parity-select trick (Fig. 5):

      +x neighbor:  jx = ix + phi_out       -> sel(phi, roll(-1), id)
      -x neighbor:  jx = ix - (1 - phi_out) -> sel(phi, id, roll(+1))
    """
    vr, vi = v
    if mu == 0:
        mask = row_parity_mask(vr.shape[:4], p_out, extra_dims)
        if sign > 0:
            rolled = (
                jnp.roll(vr, -1, axis=AX_XH),
                jnp.roll(vi, -1, axis=AX_XH),
            )
            return (
                jnp.where(mask, rolled[0], vr),
                jnp.where(mask, rolled[1], vi),
            )
        rolled = (jnp.roll(vr, 1, axis=AX_XH), jnp.roll(vi, 1, axis=AX_XH))
        return (
            jnp.where(mask, vr, rolled[0]),
            jnp.where(mask, vi, rolled[1]),
        )
    axis = {1: AX_Y, 2: AX_Z, 3: AX_T}[mu]
    return jnp.roll(vr, -sign, axis=axis), jnp.roll(vi, -sign, axis=axis)


def _project(p, mu: int, sign: int):
    """4-spinor -> 2-half-spinor projection for (1 -+ g_mu).

    p: (re, im) arrays of shape (T,Z,Y,XH,4,3).
    Returns (re, im) arrays of shape (T,Z,Y,XH,2,3).
    """
    pr, pi = p
    j1, c1, j2, c2, _, _, _, _ = PROJ[(mu, sign)]
    h1 = _cadd(
        (pr[..., 0, :], pi[..., 0, :]),
        _cmul_const((pr[..., j1, :], pi[..., j1, :]), c1),
    )
    h2 = _cadd(
        (pr[..., 1, :], pi[..., 1, :]),
        _cmul_const((pr[..., j2, :], pi[..., j2, :]), c2),
    )
    hr = jnp.stack([h1[0], h2[0]], axis=-2)
    hi = jnp.stack([h1[1], h2[1]], axis=-2)
    return hr, hi


def _reconstruct_accum(acc, w, mu: int, sign: int):
    """Accumulate the reconstructed 4-spinor from the half-spinor ``w``.

    acc: list of 4 (re, im) pairs, each (T,Z,Y,XH,3).
    w:   (re, im) arrays of shape (T,Z,Y,XH,2,3).
    """
    wr, wi = w
    _, _, _, _, k1, d1, k2, d2 = PROJ[(mu, sign)]
    h = [(wr[..., 0, :], wi[..., 0, :]), (wr[..., 1, :], wi[..., 1, :])]
    acc[0] = _cadd(acc[0], h[0])
    acc[1] = _cadd(acc[1], h[1])
    acc[2] = _cadd(acc[2], _cmul_const(h[k1], d1))
    acc[3] = _cadd(acc[3], _cmul_const(h[k2], d2))
    return acc


def _su3_mul(u, h):
    """w_a = sum_b U[a,b] h[s,b] on split re/im arrays.

    u: (re, im), shape (T,Z,Y,XH,3,3); h: (re, im), shape (T,Z,Y,XH,2,3).
    """
    ur, ui = u
    hr, hi = h
    wr = jnp.einsum("...ab,...sb->...sa", ur, hr) - jnp.einsum(
        "...ab,...sb->...sa", ui, hi
    )
    wi = jnp.einsum("...ab,...sb->...sa", ur, hi) + jnp.einsum(
        "...ab,...sb->...sa", ui, hr
    )
    return wr, wi


def _su3_dag_mul(u, h):
    """w_a = sum_b conj(U[b,a]) h[s,b] (U-dagger times half-spinor)."""
    ur, ui = u
    hr, hi = h
    wr = jnp.einsum("...ba,...sb->...sa", ur, hr) + jnp.einsum(
        "...ba,...sb->...sa", ui, hi
    )
    wi = jnp.einsum("...ba,...sb->...sa", ur, hi) - jnp.einsum(
        "...ba,...sb->...sa", ui, hr
    )
    return wr, wi


def _hopping_kernel(ur_ref, ui_ref, pr_ref, pi_ref, or_ref, oi_ref, *, p_out: int):
    """Pallas kernel body: out = H_{p_out <- p_in} psi.

    ur/ui: (4, 2, T, Z, Y, XH, 3, 3)  gauge links per direction and parity
    pr/pi: (T, Z, Y, XH, 4, 3)        source spinor, parity p_in = 1 - p_out
    or/oi: (T, Z, Y, XH, 4, 3)        result, parity p_out
    """
    p_in = 1 - p_out
    pr = pr_ref[...]
    pi = pi_ref[...]
    zero = jnp.zeros(pr.shape[:4] + (3,), pr.dtype)
    acc = [(zero, zero) for _ in range(4)]

    for mu in range(4):
        # ---- forward: (1 - g_mu) U_mu^{(p_out)}(x) psi(x + mu) ----------
        psi_fwd = shift_to_neighbor((pr, pi), mu, +1, p_out, extra_dims=2)
        h = _project(psi_fwd, mu, 0)
        u = (ur_ref[mu, p_out], ui_ref[mu, p_out])
        w = _su3_mul(u, h)
        acc = _reconstruct_accum(acc, w, mu, 0)

        # ---- backward: (1 + g_mu) U_mu^dag(x - mu) psi(x - mu) ---------
        # Project and color-multiply on the *source* parity sites, then
        # shift the half-spinor field backward (projection commutes with
        # the site shift; multiplying before the shift uses the link
        # stored at the source site, exactly U_mu(x - mu)).
        h = _project((pr, pi), mu, 1)
        u = (ur_ref[mu, p_in], ui_ref[mu, p_in])
        w = _su3_dag_mul(u, h)
        w = shift_to_neighbor(w, mu, -1, p_out, extra_dims=2)
        acc = _reconstruct_accum(acc, w, mu, 1)

    or_ref[...] = jnp.stack([a[0] for a in acc], axis=-2)
    oi_ref[...] = jnp.stack([a[1] for a in acc], axis=-2)


@functools.partial(jax.jit, static_argnames=("p_out",))
def hopping_eo(ur, ui, pr, pi, p_out: int):
    """Apply the even-odd hopping block via the Pallas kernel.

    Args:
      ur, ui: gauge field (4, 2, T, Z, Y, XH, 3, 3) float32
      pr, pi: spinor (T, Z, Y, XH, 4, 3) float32, parity ``1 - p_out``
      p_out: parity of the result (0: D_eo-like, 1: D_oe-like)

    Returns (hr, hi) of the same shape as (pr, pi), parity ``p_out``.
    """
    out_shape = [
        jax.ShapeDtypeStruct(pr.shape, pr.dtype),
        jax.ShapeDtypeStruct(pi.shape, pi.dtype),
    ]
    kernel = functools.partial(_hopping_kernel, p_out=p_out)
    return pl.pallas_call(kernel, out_shape=out_shape, interpret=True)(
        ur, ui, pr, pi
    )
