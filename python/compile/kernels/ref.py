"""Pure-jnp reference oracle for the Wilson fermion matrix (Eq. 1).

Everything here is written for clarity, not speed: explicit 4x4 gamma
matrices, complex dtypes, ``jnp.roll`` shifts on the *uncompacted* lattice.
The optimized Pallas kernel (``wilson.py``) and the Rust kernels are tested
against this module (directly, and through golden data on disk).

Conventions (see DESIGN.md section 8):
  * DeGrand-Rossi chiral basis for the gamma matrices.
  * D_W = 1 - kappa * H,    H = sum_mu [(1-g_mu) U_mu(x) delta_{x+mu,y}
                                       + (1+g_mu) U_mu^dag(x-mu) delta_{x-mu,y}]
  * canonical field shapes: spinor (T, Z, Y, X, 4, 3) complex,
    gauge (4, T, Z, Y, X, 3, 3) complex, direction order (x, y, z, t).
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

# Axis of the canonical (T, Z, Y, X, ...) array moved by direction mu.
MU_AXIS = {0: 3, 1: 2, 2: 1, 3: 0}

_I = 1j

# DeGrand-Rossi gamma matrices, direction order (x, y, z, t).
GAMMA = np.array(
    [
        # gamma_x
        [[0, 0, 0, _I], [0, 0, _I, 0], [0, -_I, 0, 0], [-_I, 0, 0, 0]],
        # gamma_y
        [[0, 0, 0, -1], [0, 0, 1, 0], [0, 1, 0, 0], [-1, 0, 0, 0]],
        # gamma_z
        [[0, 0, _I, 0], [0, 0, 0, -_I], [-_I, 0, 0, 0], [0, _I, 0, 0]],
        # gamma_t
        [[0, 0, 1, 0], [0, 0, 0, 1], [1, 0, 0, 0], [0, 1, 0, 0]],
    ],
    dtype=np.complex128,
)

GAMMA5 = np.diag([1, 1, -1, -1]).astype(np.complex128)

IDENTITY4 = np.eye(4, dtype=np.complex128)


def gamma_mul(mu: int, psi: jnp.ndarray) -> jnp.ndarray:
    """Apply gamma_mu to the spinor index: (g psi)_i = g[i,j] psi_j."""
    g = jnp.asarray(GAMMA[mu], dtype=psi.dtype)
    return jnp.einsum("ij,...jc->...ic", g, psi)


def gamma5_mul(psi: jnp.ndarray) -> jnp.ndarray:
    g5 = jnp.asarray(GAMMA5, dtype=psi.dtype)
    return jnp.einsum("ij,...jc->...ic", g5, psi)


def link_mul(u_mu: jnp.ndarray, psi: jnp.ndarray) -> jnp.ndarray:
    """U_mu(x) psi(x): 3x3 color matrix times the color index."""
    return jnp.einsum("...ab,...ib->...ia", u_mu, psi)


def link_dag_mul(u_mu: jnp.ndarray, psi: jnp.ndarray) -> jnp.ndarray:
    """U_mu(x)^dagger psi(x)."""
    return jnp.einsum("...ba,...ib->...ia", jnp.conj(u_mu), psi)


def shift(field: jnp.ndarray, mu: int, sign: int) -> jnp.ndarray:
    """Return f(x + sign*mu_hat) as a field of x (periodic)."""
    return jnp.roll(field, -sign, axis=MU_AXIS[mu])


def hopping(u: jnp.ndarray, psi: jnp.ndarray) -> jnp.ndarray:
    """The full-lattice hopping sum H psi (Eq. 1 without the 1 and -kappa)."""
    out = jnp.zeros_like(psi)
    for mu in range(4):
        # forward: (1 - gamma_mu) U_mu(x) psi(x + mu)
        fwd = link_mul(u[mu], shift(psi, mu, +1))
        out = out + fwd - gamma_mul(mu, fwd)
        # backward: (1 + gamma_mu) U_mu(x-mu)^dag psi(x - mu)
        bwd = shift(link_dag_mul(u[mu], psi), mu, -1)
        out = out + bwd + gamma_mul(mu, bwd)
    return out


def dslash(u: jnp.ndarray, psi: jnp.ndarray, kappa: float) -> jnp.ndarray:
    """Full Wilson matrix D_W psi = psi - kappa * H psi."""
    return psi - kappa * hopping(u, psi)


def plaquette(u: jnp.ndarray) -> jnp.ndarray:
    """Average plaquette Re tr P_{mu,nu} / 3, averaged over the 6 planes."""
    total = 0.0
    for mu in range(4):
        for nu in range(mu + 1, 4):
            u_mu = u[mu]
            u_nu = u[nu]
            u_nu_xmu = shift(u_nu, mu, +1)
            u_mu_xnu = shift(u_mu, nu, +1)
            # P = U_mu(x) U_nu(x+mu) U_mu(x+nu)^dag U_nu(x)^dag
            p = jnp.einsum(
                "...ab,...bc,...dc,...ed->...ae",
                u_mu,
                u_nu_xmu,
                jnp.conj(u_mu_xnu),
                jnp.conj(u_nu),
            )
            total = total + jnp.mean(jnp.real(jnp.trace(p, axis1=-2, axis2=-1)))
    return total / (6.0 * 3.0)


# ---------------------------------------------------------------------------
# Even-odd reference built on the full-lattice oracle.
# ---------------------------------------------------------------------------


def hopping_eo_via_full(u, psi_src, dims, p_out: int):
    """Reference H_{p_out <- 1-p_out} acting on a *compacted* source.

    Scatters the compacted source onto the full lattice (zeros on the other
    parity), applies the full hopping, and compacts the result at parity
    ``p_out``. Used as the oracle for the compacted Pallas/Rust kernels.

    u: full-lattice gauge (4, T, Z, Y, X, 3, 3)
    psi_src: compacted (T, Z, Y, XH, 4, 3) of parity 1 - p_out
    """
    from compile import layouts

    p_in = 1 - p_out
    src = np.asarray(psi_src)
    zeros = np.zeros_like(src)
    full = layouts.scatter(
        src if p_in == 0 else zeros, src if p_in == 1 else zeros, dims
    )
    h = hopping(jnp.asarray(u), jnp.asarray(full))
    return jnp.asarray(layouts.compact(np.asarray(h), dims, p_out))
