"""Lattice layouts: lexical <-> even-odd compacted index maps.

This mirrors Fig. 3 / Fig. 4 of the paper: sites of one parity are stored
*compacted in the x-direction* (XH = NX/2 entries per row), and the 2D
x-y SIMD tiling packs a VLENX x VLENY patch of the compacted x-y plane
into one SIMD vector of VLEN = VLENX * VLENY lanes.

Within JAX/XLA the physical packing of the trailing axes is chosen by the
compiler, so the *logical* layout here is the canonical
``(T, Z, Y, XH, spin, color)`` order; the Rust side owns the explicit
AoSoA tiling and uses these maps (via golden data) to agree with us.

Conventions (shared with rust/src/lattice/evenodd.rs):
  * site parity  p(x,y,z,t) = (x + y + z + t) mod 2  (0 = even)
  * row parity   phi(y,z,t; p) = (y + z + t + p) mod 2
  * a site of parity ``p`` at compact index ``ix`` has  x = 2*ix + phi
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class LatticeDims:
    """Local lattice extents. ``x`` must be even (even-odd compaction)."""

    x: int
    y: int
    z: int
    t: int

    def __post_init__(self) -> None:
        for name in ("x", "y", "z", "t"):
            v = getattr(self, name)
            if v < 2:
                raise ValueError(f"N{name.upper()} must be >= 2, got {v}")
            if v % 2 != 0:
                # Odd extents make site parity ill-defined under the
                # periodic wrap (the neighbor across the boundary would
                # have the *same* parity), so even-odd needs all-even dims.
                raise ValueError(f"N{name.upper()} must be even for even-odd layout, got {v}")

    @property
    def xh(self) -> int:
        """Compacted x extent (NX / NEO)."""
        return self.x // 2

    @property
    def volume(self) -> int:
        return self.x * self.y * self.z * self.t

    @property
    def half_volume(self) -> int:
        return self.volume // 2

    def shape_full(self) -> tuple[int, int, int, int]:
        """Canonical (T, Z, Y, X) array shape of the full lattice."""
        return (self.t, self.z, self.y, self.x)

    def shape_eo(self) -> tuple[int, int, int, int]:
        """Canonical (T, Z, Y, XH) array shape of one parity."""
        return (self.t, self.z, self.y, self.xh)


def site_parity(dims: LatticeDims) -> np.ndarray:
    """Parity (0 even / 1 odd) for every site, shape (T, Z, Y, X)."""
    t, z, y, x = np.ix_(
        np.arange(dims.t), np.arange(dims.z), np.arange(dims.y), np.arange(dims.x)
    )
    return (x + y + z + t) % 2


def row_parity(dims: LatticeDims, parity: int) -> np.ndarray:
    """phi(y,z,t;p) = (y+z+t+p) mod 2, shape (T, Z, Y).

    A site of parity ``parity`` at compacted index ``ix`` in row (y,z,t)
    sits at lexical x = 2*ix + phi.
    """
    t, z, y = np.ix_(np.arange(dims.t), np.arange(dims.z), np.arange(dims.y))
    return (y + z + t + parity) % 2


def compact(field: np.ndarray, dims: LatticeDims, parity: int) -> np.ndarray:
    """Extract the ``parity`` sites of a full-lattice field.

    ``field`` has shape (T, Z, Y, X, ...); returns (T, Z, Y, XH, ...),
    compacted in x as in Fig. 4 (right panel).
    """
    if field.shape[:4] != dims.shape_full():
        raise ValueError(f"field shape {field.shape[:4]} != {dims.shape_full()}")
    phi = row_parity(dims, parity)  # (T,Z,Y)
    ix = np.arange(dims.xh)
    # lexical x for each (t,z,y,ix)
    xs = 2 * ix[None, None, None, :] + phi[..., None]  # (T,Z,Y,XH)
    tt, zz, yy = np.ix_(np.arange(dims.t), np.arange(dims.z), np.arange(dims.y))
    return field[tt[..., None], zz[..., None], yy[..., None], xs]


def scatter(even: np.ndarray, odd: np.ndarray, dims: LatticeDims) -> np.ndarray:
    """Inverse of :func:`compact`: interleave even/odd arrays to full lattice."""
    inner = even.shape[4:]
    out = np.zeros(dims.shape_full() + inner, dtype=even.dtype)
    for parity, arr in ((0, even), (1, odd)):
        phi = row_parity(dims, parity)
        ix = np.arange(dims.xh)
        xs = 2 * ix[None, None, None, :] + phi[..., None]
        tt, zz, yy = np.ix_(np.arange(dims.t), np.arange(dims.z), np.arange(dims.y))
        out[tt[..., None], zz[..., None], yy[..., None], xs] = arr
    return out


def check_tiling(dims: LatticeDims, vlenx: int, vleny: int, vlen: int = 16) -> None:
    """Validate a 2D SIMD tiling choice against the local lattice.

    Mirrors the paper's constraints: VLENX * VLENY = VLEN, VLENX >= 2
    (even-odd halves x), XH divisible by VLENX, Y divisible by VLENY.
    Raises ValueError when the combination is unavailable — e.g. the
    Table 1 dash for 16x1 tiling on the 16^4 lattice.
    """
    if vlenx * vleny != vlen:
        raise ValueError(f"VLENX*VLENY = {vlenx * vleny} != VLEN = {vlen}")
    if vlenx < 2:
        raise ValueError("VLENX must be >= 2 (even-odd compaction halves x)")
    if dims.xh % vlenx != 0:
        raise ValueError(
            f"XH = {dims.xh} not divisible by VLENX = {vlenx} (tiling unavailable)"
        )
    if dims.y % vleny != 0:
        raise ValueError(
            f"NY = {dims.y} not divisible by VLENY = {vleny} (tiling unavailable)"
        )
