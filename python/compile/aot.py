"""AOT compile path: lower the L2 graphs to HLO text + manifest + golden data.

Run once at build time (``make artifacts``); the Rust runtime then loads
``artifacts/*.hlo.txt`` through the PJRT C API and Python never appears on
the request path again.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids and round-trips cleanly.

Also emits ``artifacts/golden/`` — seeded random fields and reference
results (computed with the pure-jnp oracle in float64) that pin the Rust
native kernels to the exact conventions used here.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import fieldio, layouts, model
from compile.kernels import ref

GOLDEN_KAPPA = 0.13


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def parse_dims(spec: str) -> layouts.LatticeDims:
    parts = [int(p) for p in spec.lower().split("x")]
    if len(parts) != 4:
        raise ValueError(f"dims must be NXxNYxNZxNT, got {spec!r}")
    return layouts.LatticeDims(x=parts[0], y=parts[1], z=parts[2], t=parts[3])


def _dtype_name(dt) -> str:
    return {"float32": "f32", "float64": "f64", "int32": "i32"}[np.dtype(dt).name]


def lower_all(dims: layouts.LatticeDims, out_dir: pathlib.Path, tol, maxiter):
    entries = []
    eps = model.make_entry_points(dims, tol=tol, maxiter=maxiter)
    for name, (fn, specs) in eps.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        (out_dir / fname).write_text(text)
        out_specs = jax.eval_shape(fn, *specs)
        out_list = (
            list(out_specs) if isinstance(out_specs, (tuple, list)) else [out_specs]
        )
        entries.append(
            {
                "name": name,
                "file": fname,
                "inputs": [
                    {"shape": list(s.shape), "dtype": _dtype_name(s.dtype)}
                    for s in specs
                ],
                "outputs": [
                    {"shape": list(s.shape), "dtype": _dtype_name(s.dtype)}
                    for s in out_list
                ],
            }
        )
        print(f"  lowered {name:16s} -> {fname} ({len(text)} chars)")
    return entries


# ---------------------------------------------------------------------------
# Golden data
# ---------------------------------------------------------------------------


def random_su3(rng: np.random.Generator, shape) -> np.ndarray:
    """Random SU(3) field of the given site shape (+ trailing 3x3)."""
    a = rng.normal(size=shape + (3, 3)) + 1j * rng.normal(size=shape + (3, 3))
    q, r = np.linalg.qr(a)
    # make the decomposition unique and det = 1
    d = np.diagonal(r, axis1=-2, axis2=-1)
    q = q * (d / np.abs(d))[..., None, :]
    det = np.linalg.det(q)
    return q / det[..., None, None] ** (1.0 / 3.0)


def compact_gauge(u_full: np.ndarray, dims: layouts.LatticeDims) -> np.ndarray:
    """Lexical gauge (4,T,Z,Y,X,3,3) -> even-odd (4,2,T,Z,Y,XH,3,3)."""
    out = np.zeros((4, 2) + dims.shape_eo() + (3, 3), dtype=u_full.dtype)
    for mu in range(4):
        for p in range(2):
            out[mu, p] = layouts.compact(u_full[mu], dims, p)
    return out


def write_golden(dims: layouts.LatticeDims, out_dir: pathlib.Path, seed: int = 20230227):
    gdir = out_dir / "golden"
    gdir.mkdir(parents=True, exist_ok=True)
    rng = np.random.default_rng(seed)
    kappa = GOLDEN_KAPPA

    # inputs are generated in f32 precision, reference math runs in f64/c128
    u_full = random_su3(rng, (4,) + dims.shape_full()).astype(np.complex64)
    u_full = u_full.astype(np.complex128)
    psi_e = (
        rng.normal(size=dims.shape_eo() + (4, 3))
        + 1j * rng.normal(size=dims.shape_eo() + (4, 3))
    ).astype(np.complex64).astype(np.complex128)
    psi_o = (
        rng.normal(size=dims.shape_eo() + (4, 3))
        + 1j * rng.normal(size=dims.shape_eo() + (4, 3))
    ).astype(np.complex64).astype(np.complex128)
    psi_full = (
        rng.normal(size=dims.shape_full() + (4, 3))
        + 1j * rng.normal(size=dims.shape_full() + (4, 3))
    ).astype(np.complex64).astype(np.complex128)

    u_eo = compact_gauge(u_full, dims)

    with jax.enable_x64(True):
        hop_oe = np.asarray(ref.hopping_eo_via_full(u_full, psi_e, dims, p_out=1))
        hop_eo = np.asarray(ref.hopping_eo_via_full(u_full, psi_o, dims, p_out=0))
        # M-hat psi_e = psi_e - kappa^2 H_eo H_oe psi_e
        h_o = ref.hopping_eo_via_full(u_full, psi_e, dims, p_out=1)
        meo_res = np.asarray(psi_e - kappa * kappa * np.asarray(
            ref.hopping_eo_via_full(u_full, np.asarray(h_o), dims, p_out=0)
        ))
        dslash_full = np.asarray(ref.dslash(jnp.asarray(u_full), jnp.asarray(psi_full), kappa))
        plaq = float(ref.plaquette(jnp.asarray(u_full)))

    files = {
        "u_full": fieldio.complex_to_interleaved(u_full),
        "u_eo": fieldio.complex_to_interleaved(u_eo),
        "psi_e": fieldio.complex_to_interleaved(psi_e),
        "psi_o": fieldio.complex_to_interleaved(psi_o),
        "psi_full": fieldio.complex_to_interleaved(psi_full),
        "hop_oe": fieldio.complex_to_interleaved(hop_oe),
        "hop_eo": fieldio.complex_to_interleaved(hop_eo),
        "meo": fieldio.complex_to_interleaved(meo_res),
        "dslash_full": fieldio.complex_to_interleaved(dslash_full),
        "plaq": np.array([plaq], dtype=np.float64),
    }
    for name, arr in files.items():
        fieldio.write_tensor(gdir / f"{name}.bin", arr)
    print(f"  golden data ({dims.x}x{dims.y}x{dims.z}x{dims.t}, kappa={kappa}) -> {gdir}")
    return {
        "dims": [dims.x, dims.y, dims.z, dims.t],
        "kappa": kappa,
        "seed": seed,
        "files": sorted(files),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--dims", default="8x8x8x16", help="artifact lattice NXxNYxNZxNT")
    ap.add_argument("--golden-dims", default="4x4x4x4")
    ap.add_argument("--tol", type=float, default=1e-10, help="baked CG tolerance (on |r|^2)")
    ap.add_argument("--maxiter", type=int, default=1000)
    ap.add_argument("--skip-golden", action="store_true")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    dims = parse_dims(args.dims)

    print(f"lowering artifacts for lattice {args.dims} ...")
    entries = lower_all(dims, out_dir, tol=args.tol, maxiter=args.maxiter)

    golden_meta = None
    if not args.skip_golden:
        golden_meta = write_golden(parse_dims(args.golden_dims), out_dir)

    manifest = {
        "version": 1,
        "dims": [dims.x, dims.y, dims.z, dims.t],
        "cg_tol": args.tol,
        "cg_maxiter": args.maxiter,
        "artifacts": entries,
        "golden": golden_meta,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2) + "\n")
    print(f"wrote {out_dir / 'manifest.json'}")


if __name__ == "__main__":
    main()
