"""AOT path: lowering must produce parseable HLO text with the expected
interfaces (shape and count), and the golden-data generator must be
deterministic in its seed."""

import numpy as np
import pytest

import jax

from compile import aot, layouts, model


def test_parse_dims():
    d = aot.parse_dims("16x8x4x6")
    assert (d.x, d.y, d.z, d.t) == (16, 8, 4, 6)
    with pytest.raises(ValueError):
        aot.parse_dims("16x8x4")
    with pytest.raises(ValueError):
        aot.parse_dims("15x8x4x6")  # odd extent


def test_entry_points_cover_required_artifacts():
    dims = layouts.LatticeDims(4, 4, 4, 4)
    eps = model.make_entry_points(dims)
    for required in [
        "hopping_oe",
        "hopping_eo",
        "meo",
        "mdagm",
        "cg_solve",
        "reconstruct_odd",
        "plaquette",
    ]:
        assert required in eps, f"missing artifact {required}"


def test_lowered_hlo_text_is_hlo():
    """One small entry point lowered end-to-end: text must be HLO."""
    dims = layouts.LatticeDims(4, 4, 2, 2)
    fn, specs = model.make_entry_points(dims)["hopping_oe"]
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # the module must return a tuple (return_tuple=True contract with rust)
    assert "tuple" in text


def test_hopping_artifact_shapes():
    dims = layouts.LatticeDims(4, 4, 2, 2)
    fn, specs = model.make_entry_points(dims)["meo"]
    out = jax.eval_shape(fn, *specs)
    assert tuple(out.shape) == (2, 2, 4, 2, 4, 3, 2)  # (T,Z,Y,XH,4,3,2)
    # u, psi, kappa
    assert len(specs) == 3
    assert specs[2].shape == ()


def test_random_su3_is_unitary_det1():
    rng = np.random.default_rng(5)
    u = aot.random_su3(rng, (10,))
    eye = np.eye(3)
    for m in u:
        np.testing.assert_allclose(m @ m.conj().T, eye, atol=1e-12)
        np.testing.assert_allclose(np.linalg.det(m), 1.0, atol=1e-12)


def test_compact_gauge_roundtrip_content():
    dims = layouts.LatticeDims(4, 4, 2, 2)
    rng = np.random.default_rng(6)
    u_full = aot.random_su3(rng, (4,) + dims.shape_full())
    u_eo = aot.compact_gauge(u_full, dims)
    assert u_eo.shape == (4, 2) + dims.shape_eo() + (3, 3)
    # scattering even+odd links back must reproduce the full field
    for mu in range(4):
        back = layouts.scatter(u_eo[mu, 0], u_eo[mu, 1], dims)
        np.testing.assert_array_equal(back, u_full[mu])


def test_golden_deterministic(tmp_path):
    dims = layouts.LatticeDims(2, 2, 2, 2)
    meta1 = aot.write_golden(dims, tmp_path / "a", seed=7)
    meta2 = aot.write_golden(dims, tmp_path / "b", seed=7)
    assert meta1["files"] == meta2["files"]
    from compile import fieldio

    for name in meta1["files"]:
        a = fieldio.read_tensor(tmp_path / "a" / "golden" / f"{name}.bin")
        b = fieldio.read_tensor(tmp_path / "b" / "golden" / f"{name}.bin")
        np.testing.assert_array_equal(a, b)
