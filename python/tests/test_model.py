"""L2 correctness: even-odd preconditioned operator, CG solver, plaquette."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import layouts, model
from compile.kernels import ref
from tests.test_kernel import compact_gauge, make_fields, random_su3

DIMS = layouts.LatticeDims(4, 4, 4, 4)
KAPPA = 0.13


def interleave(c):
    return np.stack([c.real, c.imag], axis=-1).astype(np.float32)


def to_complex(a):
    return np.asarray(a)[..., 0] + 1j * np.asarray(a)[..., 1]


@pytest.fixture(scope="module")
def fields():
    u, psi_e = make_fields(DIMS, seed=11)
    _, psi_o = make_fields(DIMS, seed=12)
    u_eo = interleave(compact_gauge(u, DIMS))
    return u, u_eo, psi_e, psi_o


def test_meo_matches_schur_complement(fields):
    """M-hat psi_e == psi_e - kappa^2 H_eo H_oe psi_e via the oracle."""
    u, u_eo, psi_e, _ = fields
    got = to_complex(model.meo(jnp.asarray(u_eo), jnp.asarray(interleave(psi_e)), KAPPA))
    h_o = np.asarray(ref.hopping_eo_via_full(u, psi_e, DIMS, p_out=1))
    h_e = np.asarray(ref.hopping_eo_via_full(u, h_o, DIMS, p_out=0))
    want = psi_e - KAPPA**2 * h_e
    np.testing.assert_allclose(got, want, atol=5e-5)


def test_gamma5_hermiticity(fields):
    """<x, M y> == <g5 M g5 x, y> for random x, y (M-hat^dag = g5 M-hat g5)."""
    _, u_eo, psi_e, psi_o = fields
    x, y = interleave(psi_e), interleave(psi_o)
    u_eo = jnp.asarray(u_eo)
    my = to_complex(model.meo(u_eo, jnp.asarray(y), KAPPA))
    mdx = to_complex(model.meo_dag(u_eo, jnp.asarray(x), KAPPA))
    xc, yc = to_complex(x), to_complex(y)
    lhs = np.vdot(xc, my)
    rhs = np.vdot(mdx, yc)
    np.testing.assert_allclose(lhs, rhs, rtol=2e-4)


def test_mdagm_hermitian_positive(fields):
    _, u_eo, psi_e, psi_o = fields
    u_eo = jnp.asarray(u_eo)
    x, y = interleave(psi_e), interleave(psi_o)
    ax = to_complex(model.mdagm(u_eo, jnp.asarray(x), KAPPA))
    ay = to_complex(model.mdagm(u_eo, jnp.asarray(y), KAPPA))
    xc, yc = to_complex(x), to_complex(y)
    np.testing.assert_allclose(np.vdot(xc, ay), np.conj(np.vdot(yc, ax)), rtol=2e-4)
    assert np.vdot(xc, ax).real > 0
    assert abs(np.vdot(xc, ax).imag) < 1e-3 * abs(np.vdot(xc, ax).real)


def test_cg_solves(fields):
    """CG returns x with M-hat x == b to the requested tolerance."""
    _, u_eo, psi_e, _ = fields
    u_eo = jnp.asarray(u_eo)
    b = jnp.asarray(interleave(psi_e))
    x, iters, rr = model.cg_solve(u_eo, b, KAPPA, tol=1e-8, maxiter=500)
    assert int(iters) < 500
    mx = to_complex(model.meo(u_eo, x, KAPPA))
    bc = to_complex(b)
    resid = np.linalg.norm(mx - bc) / np.linalg.norm(bc)
    assert resid < 1e-5, f"true residual {resid}"


def test_even_odd_solution_solves_full_system(fields):
    """Schur solve (Eqs. 4+5) reproduces a solution of the full D psi = eta."""
    u, u_eo, psi_e, psi_o = fields
    u_eo_j = jnp.asarray(u_eo)
    b_e, b_o = jnp.asarray(interleave(psi_e)), jnp.asarray(interleave(psi_o))
    # rhs of Eq. 4: b_e + kappa H_eo b_o   (D_ee = 1)
    rhs = b_e + KAPPA * model.hopping(u_eo_j, b_o, p_out=0)
    x_e, _, _ = model.cg_solve(u_eo_j, rhs, KAPPA, tol=1e-8, maxiter=500)
    x_o = model.reconstruct_odd(u_eo_j, b_o, x_e, KAPPA)
    # verify on the full lattice against the oracle
    full_x = layouts.scatter(to_complex(x_e), to_complex(x_o), DIMS)
    full_b = layouts.scatter(to_complex(b_e), to_complex(b_o), DIMS)
    dx = np.asarray(ref.dslash(jnp.asarray(u.astype(np.complex128)), jnp.asarray(full_x), KAPPA))
    resid = np.linalg.norm(dx - full_b) / np.linalg.norm(full_b)
    assert resid < 1e-5, f"full-system residual {resid}"


def test_dslash_eo_full_matches_oracle(fields):
    u, u_eo, psi_e, psi_o = fields
    out_e, out_o = model.dslash_eo_full(
        jnp.asarray(u_eo),
        jnp.asarray(interleave(psi_e)),
        jnp.asarray(interleave(psi_o)),
        KAPPA,
    )
    full = layouts.scatter(psi_e, psi_o, DIMS)
    want = np.asarray(ref.dslash(jnp.asarray(u.astype(np.complex128)), jnp.asarray(full), KAPPA))
    got = layouts.scatter(to_complex(out_e), to_complex(out_o), DIMS)
    np.testing.assert_allclose(got, want, atol=5e-5)


def test_plaquette_unit_gauge():
    u = np.zeros((4,) + DIMS.shape_full() + (3, 3, 2), dtype=np.float32)
    u[..., np.arange(3), np.arange(3), 0] = 1.0
    got = float(model.plaquette(jnp.asarray(u)))
    np.testing.assert_allclose(got, 1.0, atol=1e-6)


def test_plaquette_random_gauge_matches_ref():
    rng = np.random.default_rng(21)
    u = random_su3(rng, (4,) + DIMS.shape_full()).astype(np.complex64)
    got = float(model.plaquette(jnp.asarray(interleave(u))))
    want = float(ref.plaquette(jnp.asarray(u)))
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_plaquette_gauge_invariance():
    """Plaquette is invariant under a random gauge transformation."""
    rng = np.random.default_rng(22)
    u = random_su3(rng, (4,) + DIMS.shape_full()).astype(np.complex128)
    g = random_su3(rng, DIMS.shape_full()).astype(np.complex128)
    ug = np.empty_like(u)
    for mu in range(4):
        g_shift = np.roll(g, -1, axis=ref.MU_AXIS[mu])
        ug[mu] = np.einsum("...ab,...bc,...dc->...ad", g, u[mu], np.conj(g_shift))
    p0 = float(ref.plaquette(jnp.asarray(u)))
    p1 = float(ref.plaquette(jnp.asarray(ug)))
    np.testing.assert_allclose(p1, p0, atol=1e-10)
