"""L1 correctness: Pallas kernel vs the pure-jnp oracle.

This is the CORE correctness signal of the compile path. The compacted
kernel is checked against the uncompacted full-lattice reference over a
hypothesis-driven sweep of lattice shapes and both output parities.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import layouts
from compile.kernels import ref, wilson


def random_su3(rng, shape):
    a = rng.normal(size=shape + (3, 3)) + 1j * rng.normal(size=shape + (3, 3))
    q, r = np.linalg.qr(a)
    d = np.diagonal(r, axis1=-2, axis2=-1)
    q = q * (d / np.abs(d))[..., None, :]
    det = np.linalg.det(q)
    return q / det[..., None, None] ** (1.0 / 3.0)


def make_fields(dims, seed=0):
    rng = np.random.default_rng(seed)
    u = random_su3(rng, (4,) + dims.shape_full()).astype(np.complex64)
    psi = (
        rng.normal(size=dims.shape_eo() + (4, 3))
        + 1j * rng.normal(size=dims.shape_eo() + (4, 3))
    ).astype(np.complex64)
    return u, psi


def compact_gauge(u, dims):
    out = np.zeros((4, 2) + dims.shape_eo() + (3, 3), dtype=u.dtype)
    for mu in range(4):
        for p in range(2):
            out[mu, p] = layouts.compact(u[mu], dims, p)
    return out


def run_kernel(u, psi, dims, p_out):
    u_eo = compact_gauge(u, dims)
    hr, hi = wilson.hopping_eo(
        jnp.asarray(u_eo.real, jnp.float32),
        jnp.asarray(u_eo.imag, jnp.float32),
        jnp.asarray(psi.real, jnp.float32),
        jnp.asarray(psi.imag, jnp.float32),
        p_out,
    )
    return np.asarray(hr) + 1j * np.asarray(hi)


# ---------------------------------------------------------------------------
# Projection tables are DERIVED here from the explicit gamma matrices.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mu", range(4))
@pytest.mark.parametrize("sign", range(2))
def test_projection_tables(mu, sign):
    """PROJ must reproduce (1 -+ g_mu) psi exactly (sign=0 -> 1 - g_mu)."""
    rng = np.random.default_rng(mu * 2 + sign)
    psi = rng.normal(size=(4, 3)) + 1j * rng.normal(size=(4, 3))
    g = ref.GAMMA[mu]
    s = -1.0 if sign == 0 else 1.0
    expected = psi + s * (g @ psi)

    j1, c1, j2, c2, k1, d1, k2, d2 = wilson.PROJ[(mu, sign)]
    cc1, cc2 = complex(*c1), complex(*c2)
    dd1, dd2 = complex(*d1), complex(*d2)
    h1 = psi[0] + cc1 * psi[j1]
    h2 = psi[1] + cc2 * psi[j2]
    h = [h1, h2]
    got = np.stack([h1, h2, dd1 * h[k1], dd2 * h[k2]])
    np.testing.assert_allclose(got, expected, atol=1e-12)


@pytest.mark.parametrize("mu", range(4))
def test_gamma_algebra(mu):
    g = ref.GAMMA[mu]
    np.testing.assert_allclose(g @ g, np.eye(4), atol=1e-14)  # g^2 = 1
    np.testing.assert_allclose(g, g.conj().T, atol=1e-14)  # hermitian
    # {g_mu, g_nu} = 2 delta
    for nu in range(4):
        anti = g @ ref.GAMMA[nu] + ref.GAMMA[nu] @ g
        np.testing.assert_allclose(anti, 2.0 * np.eye(4) * (mu == nu), atol=1e-14)


def test_gamma5():
    g5 = ref.GAMMA[0] @ ref.GAMMA[1] @ ref.GAMMA[2] @ ref.GAMMA[3]
    np.testing.assert_allclose(g5, ref.GAMMA5, atol=1e-14)


# ---------------------------------------------------------------------------
# Kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p_out", [0, 1])
def test_kernel_vs_ref_small(p_out):
    dims = layouts.LatticeDims(4, 4, 4, 4)
    u, psi = make_fields(dims, seed=7 + p_out)
    got = run_kernel(u, psi, dims, p_out)
    want = np.asarray(ref.hopping_eo_via_full(u, psi, dims, p_out))
    np.testing.assert_allclose(got, want, rtol=0, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    nx=st.sampled_from([2, 4, 6, 8]),
    ny=st.sampled_from([2, 4, 6]),
    nz=st.sampled_from([2, 4, 6]),
    nt=st.sampled_from([2, 4]),
    p_out=st.integers(0, 1),
    seed=st.integers(0, 2**16),
)
def test_kernel_vs_ref_shapes(nx, ny, nz, nt, p_out, seed):
    """Property sweep: compacted kernel == oracle for arbitrary extents."""
    dims = layouts.LatticeDims(nx, ny, nz, nt)
    u, psi = make_fields(dims, seed=seed)
    got = run_kernel(u, psi, dims, p_out)
    want = np.asarray(ref.hopping_eo_via_full(u, psi, dims, p_out))
    np.testing.assert_allclose(got, want, rtol=0, atol=2e-5)


def test_kernel_linear():
    """H is linear: H(a x + y) = a Hx + Hy."""
    dims = layouts.LatticeDims(4, 4, 2, 2)
    u, psi1 = make_fields(dims, seed=1)
    _, psi2 = make_fields(dims, seed=2)
    a = 0.37
    lhs = run_kernel(u, a * psi1 + psi2, dims, 1)
    rhs = a * run_kernel(u, psi1, dims, 1) + run_kernel(u, psi2, dims, 1)
    np.testing.assert_allclose(lhs, rhs, atol=5e-5)


def test_free_field_hopping():
    """U = 1: H psi for constant psi must be 8 psi (sum of 8 projectors)."""
    dims = layouts.LatticeDims(4, 4, 4, 4)
    u = np.zeros((4,) + dims.shape_full() + (3, 3), dtype=np.complex64)
    u[..., np.arange(3), np.arange(3)] = 1.0
    psi = np.ones(dims.shape_eo() + (4, 3), dtype=np.complex64)
    got = run_kernel(u, psi, dims, 0)
    np.testing.assert_allclose(got, 8.0 * psi, atol=1e-4)
