"""Layout invariants: even-odd compaction maps and tiling constraints."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import fieldio, layouts

EVEN = st.sampled_from([2, 4, 6, 8])


@settings(max_examples=20, deadline=None)
@given(nx=EVEN, ny=EVEN, nz=EVEN, nt=EVEN)
def test_compact_scatter_roundtrip(nx, ny, nz, nt):
    """scatter(compact(f,0), compact(f,1)) == f (bijection, Fig. 4)."""
    dims = layouts.LatticeDims(nx, ny, nz, nt)
    rng = np.random.default_rng(nx * ny + nz * nt)
    f = rng.normal(size=dims.shape_full() + (3,))
    e = layouts.compact(f, dims, 0)
    o = layouts.compact(f, dims, 1)
    assert e.shape == dims.shape_eo() + (3,)
    np.testing.assert_array_equal(layouts.scatter(e, o, dims), f)


def test_compact_selects_parity():
    """Every site landing in the parity-p array really has parity p."""
    dims = layouts.LatticeDims(4, 4, 2, 2)
    par = layouts.site_parity(dims).astype(np.float64)
    for p in range(2):
        got = layouts.compact(par, dims, p)
        np.testing.assert_array_equal(got, np.full(dims.shape_eo(), p))


def test_row_parity_matches_x_coordinate():
    """x = 2*ix + phi recovers the lexical x coordinate."""
    dims = layouts.LatticeDims(8, 4, 2, 2)
    xcoord = np.broadcast_to(
        np.arange(dims.x), dims.shape_full()
    ).astype(np.float64)
    for p in range(2):
        compacted = layouts.compact(xcoord, dims, p)
        phi = layouts.row_parity(dims, p)
        ix = np.arange(dims.xh)
        want = 2 * ix[None, None, None, :] + phi[..., None]
        np.testing.assert_array_equal(compacted, want)


def test_odd_extent_rejected():
    with pytest.raises(ValueError):
        layouts.LatticeDims(4, 3, 4, 4)
    with pytest.raises(ValueError):
        layouts.LatticeDims(5, 4, 4, 4)


@pytest.mark.parametrize(
    "vx,vy,ok",
    [(16, 1, False), (8, 2, True), (4, 4, True), (2, 8, True)],
)
def test_table1_tilings_16x16(vx, vy, ok):
    """Table 1: the 16x1 tiling is unavailable at NX=16 (XH=8 < 16)."""
    dims = layouts.LatticeDims(16, 16, 8, 8)
    if ok:
        layouts.check_tiling(dims, vx, vy)
    else:
        with pytest.raises(ValueError):
            layouts.check_tiling(dims, vx, vy)


@pytest.mark.parametrize("vx,vy", [(16, 1), (8, 2), (4, 4), (2, 8)])
def test_table1_tilings_64x16(vx, vy):
    """All four tilings are available on the 64x16x8x4 lattice."""
    layouts.check_tiling(layouts.LatticeDims(64, 16, 8, 4), vx, vy)


def test_tiling_rejects_vlenx_1():
    with pytest.raises(ValueError):
        layouts.check_tiling(layouts.LatticeDims(64, 16, 8, 4), 1, 16)


def test_fieldio_roundtrip(tmp_path):
    rng = np.random.default_rng(3)
    for dtype in (np.float32, np.float64):
        arr = rng.normal(size=(3, 4, 5)).astype(dtype)
        p = tmp_path / f"t_{dtype.__name__}.bin"
        fieldio.write_tensor(p, arr)
        back = fieldio.read_tensor(p)
        assert back.dtype == arr.dtype
        np.testing.assert_array_equal(back, arr)


def test_fieldio_complex_roundtrip(tmp_path):
    rng = np.random.default_rng(4)
    c = rng.normal(size=(2, 3)) + 1j * rng.normal(size=(2, 3))
    inter = fieldio.complex_to_interleaved(c, dtype=np.float64)
    assert inter.shape == (2, 3, 2)
    np.testing.assert_allclose(fieldio.interleaved_to_complex(inter), c)


def test_fieldio_bad_magic(tmp_path):
    p = tmp_path / "bad.bin"
    p.write_bytes(b"NOTMAGIC" + b"\0" * 16)
    with pytest.raises(ValueError):
        fieldio.read_tensor(p)
